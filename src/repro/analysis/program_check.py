"""Pure-static verifier for RouteProgram / Topology pairs.

Proves, without executing the datapath, that a route program is a sound
circuit schedule: every invariant here is one the runtime oracle
(:func:`repro.core.ref.expected_transfer_telemetry`) or the jitted
datapath would otherwise only reveal dynamically — as silently dropped
pages, double-served pairs, gateway contention, or an out-of-range
telemetry bin index.

Everything is plain numpy over the program's four arrays (``offsets``,
``epoch``, ``live``, ``rank_epoch``) plus the static topology; no jax
import, so the checks run anywhere (CI lint job, control plane, property
suites) in microseconds.

Rule catalog (details in ``src/repro/analysis/RULES.md``):

  PC101  rank-epoch-shape      group mask is not [N-1, N]
  PC102  offset-incongruent    live slot drives an offset whose permutation
                               is not its ring distance
  PC103  offset-range          live slot offset 0 or |offset| outside 1..N-1
  PC104  dead-slot-residue     dead slot still carries offset/epoch/ranks
  PC105  idle-live-slot        live slot serves no rank (FREE-mask vs live
                               mask inconsistent)
  PC106  epoch-mismatch        slot's base epoch is not its earliest served
                               rank epoch
  PC107  epoch-out-of-range    a served rank epoch outside [0, 2(N-1)) —
                               the telemetry histograms would clip/IndexError
  PC108  gateway-contention    two slots carry board-crossing pairs in one
                               epoch (gateways are single-ported)
  PC109  ring-link-contention  two same-direction slots carry intra-board
                               pairs in one epoch (they share the ring links)
  PC110  coverage-gap          a required (requester, distance) pair is not
                               wired (exactly-once coverage)
  PC111  budget-window         transfer window insane (budget < 1,
                               active_budget outside [0, budget], ...)

:func:`coverage` is the static analogue of :func:`repro.core.ref.served_mask`:
the property suite asserts they agree on random fabrics, which is what
makes a clean :func:`check_program` verdict a *proof* that the runtime
oracle cannot prune a covered pair.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.analysis.findings import WARNING, Finding

__all__ = ["check_program", "check_transfer_window", "coverage",
           "verify_program"]


def _epoch_bins(num_nodes: int) -> int:
    """Static epoch-histogram length.  Mirrors
    ``repro.telemetry.counters.num_epoch_bins`` (kept inline so this
    module stays importable without jax)."""
    return 2 * max(num_nodes - 1, 0)


def _fields(program):
    off = np.asarray(program.offsets, np.int64)
    epoch = np.asarray(program.epoch, np.int64)
    live = np.asarray(program.live, bool)
    rank_epoch = np.asarray(program.rank_epoch, np.int64)
    return off, epoch, live, rank_epoch


def coverage(program) -> np.ndarray:
    """bool[N-1, N]: does slot k carry requester rank r's traffic.

    The static serve set — exactly what :func:`repro.core.ref.served_mask`
    answers per request at runtime: a remote (requester r, distance k+1)
    pair is served iff ``live[k] & rank_epoch[k, r] >= 0``.  (Distance 0,
    the loopback fast path, never touches the program.)
    """
    off, epoch, live, rank_epoch = _fields(program)
    n = off.shape[0] + 1
    if rank_epoch.shape != (n - 1, n):
        # shape is itself a finding (PC101); report no coverage rather
        # than index out of bounds here.
        return np.zeros((n - 1, n), bool)
    return live[:, None] & (rank_epoch >= 0)


def check_program(program, topology=None, *,
                  required_pairs: Optional[np.ndarray] = None
                  ) -> List[Finding]:
    """Statically verify a route program against a fabric.

    Args:
      program: any :class:`~repro.core.steering.RouteProgram`-shaped object
        (jax or numpy arrays — duck-typed, nothing is executed).
      topology: the :class:`~repro.core.topology.Topology` the program will
        drive; ``None`` means the flat single-board ring (every pair
        intra-board, no gateways).
      required_pairs: optional bool[N-1, N] — the (slot, rank) pairs that
        *must* be wired (e.g. from placement reachability).  Uncovered
        required pairs are PC110 findings; ``None`` skips the coverage
        check (pruned/masked programs drop pairs by design).

    Returns a list of :class:`Finding`; empty = verified sound.
    """
    out: List[Finding] = []
    off, epoch, live, rank_epoch = _fields(program)
    s = off.shape[0]
    n = s + 1
    where = "program"

    if rank_epoch.shape != (s, n):
        out.append(Finding(
            "PC101", f"rank_epoch has shape {rank_epoch.shape}; a {n}-node "
            f"ring needs {(s, n)}", path=where))
        return out  # every later check indexes the group mask

    d = np.arange(1, n)
    # PC103 first: congruence (PC102) is meaningless for out-of-range
    # offsets, so report each bad slot under exactly one rule.
    bad_range = live & ((off == 0) | (np.abs(off) > s))
    for k in np.nonzero(bad_range)[0]:
        out.append(Finding(
            "PC103", f"live slot {k} drives offset {off[k]}; a {n}-node "
            f"ring only realizes 1 <= |offset| <= {s}", path=where))
    bad_cong = live & ~bad_range & ((off % n) != d)
    for k in np.nonzero(bad_cong)[0]:
        out.append(Finding(
            "PC102", f"slot {k} serves ring distance {k + 1} but drives "
            f"offset {off[k]} (permutation rank->rank{off[k]:+d} is "
            f"distance {off[k] % n})", path=where))

    # FREE-mask conservation: dead slots must be fully FREE (the datapath
    # masks their requests; leftover state would leak into telemetry),
    # live slots must serve somebody.
    ghost = ~live & ((off != 0) | (epoch != -1) | (rank_epoch >= 0).any(1))
    for k in np.nonzero(ghost)[0]:
        out.append(Finding(
            "PC104", f"dead slot {k} still carries state (offset {off[k]}, "
            f"epoch {epoch[k]}, "
            f"{int((rank_epoch[k] >= 0).sum())} rank pairings)", path=where))
    idle = live & ~(rank_epoch >= 0).any(1)
    for k in np.nonzero(idle)[0]:
        out.append(Finding(
            "PC105", f"live slot {k} serves no rank (every pairing is "
            "FREE-masked); it should be dead", path=where))

    served = live[:, None] & (rank_epoch >= 0)
    # Base epoch must be the slot's earliest served epoch (the datapath
    # and the perfmodel order circuits by it).
    for k in np.nonzero(live & served.any(1))[0]:
        lo = int(rank_epoch[k][served[k]].min())
        if int(epoch[k]) != lo:
            out.append(Finding(
                "PC106", f"slot {k} base epoch {int(epoch[k])} != earliest "
                f"served rank epoch {lo}", path=where))

    # Epoch bin range: the telemetry histograms are statically sized to
    # 2(N-1) bins; a larger epoch IndexErrors the oracle and silently
    # clips on device.
    bins = _epoch_bins(n)
    over = served & (rank_epoch >= bins)
    for k in np.nonzero(over.any(1))[0]:
        out.append(Finding(
            "PC107", f"slot {k} schedules epochs "
            f"{sorted(set(rank_epoch[k][over[k]].tolist()))} beyond the "
            f"static {bins}-bin telemetry range", path=where))
    under = live[:, None] & (rank_epoch < -1)
    for k in np.nonzero(under.any(1))[0]:
        out.append(Finding(
            "PC107", f"slot {k} carries rank epochs < -1 "
            f"({sorted(set(rank_epoch[k][under[k]].tolist()))}); -1 is the "
            "only FREE sentinel", path=where))

    # Epoch exclusivity on the physical fabric: per epoch, at most one
    # board-crossing slot (gateway is single-ported) and at most one
    # intra-board slot per direction (same-direction circuits share every
    # directed board-ring link).  topology=None is the flat ring: every
    # pair is intra-board, so PC109 alone enforces the flat
    # one-circuit-per-direction-per-epoch rule.
    r = np.arange(n)
    valid_epochs = rank_epoch[served & (rank_epoch < bins) & (rank_epoch >= 0)]
    for e in np.unique(valid_epochs):
        inter_at_e, intra_cw, intra_ccw = [], [], []
        for k in range(s):
            ranks = np.nonzero(served[k] & (rank_epoch[k] == e))[0]
            if ranks.size == 0:
                continue
            homes = (ranks + k + 1) % n
            if topology is None:
                intra = np.ones(ranks.shape, bool)
            else:
                intra = np.asarray(topology.pair_intra(ranks, homes), bool)
            if (~intra).any():
                inter_at_e.append(k)
            if intra.any():
                (intra_cw if off[k] > 0 else intra_ccw).append(k)
        if len(inter_at_e) > 1:
            out.append(Finding(
                "PC108", f"epoch {int(e)}: slots {inter_at_e} all carry "
                "board-crossing pairs — they contend for the gateways",
                path=where))
        for name, group in (("cw", intra_cw), ("ccw", intra_ccw)):
            if len(group) > 1:
                out.append(Finding(
                    "PC109", f"epoch {int(e)}: slots {group} share the "
                    f"{name} board-ring links", path=where))

    # Exactly-once pair coverage against a required serve set.  "At most
    # once" is structural (one epoch per (slot, rank) cell); this closes
    # the "at least once" half.
    if required_pairs is not None:
        req = np.asarray(required_pairs, bool)
        if req.shape != (s, n):
            out.append(Finding(
                "PC101", f"required_pairs has shape {req.shape}; expected "
                f"{(s, n)}", path=where))
        else:
            gap = req & ~served
            for k in np.nonzero(gap.any(1))[0]:
                out.append(Finding(
                    "PC110", f"slot {k} (distance {k + 1}) does not serve "
                    f"required requesters "
                    f"{np.nonzero(gap[k])[0].tolist()}", path=where))

    if topology is not None and getattr(topology, "num_nodes", n) != n:
        out.append(Finding(
            "PC101", f"topology has {topology.num_nodes} nodes; program "
            f"has {n}", path=where))
    return out


def check_transfer_window(num_requests: int, budget: int,
                          active_budget=None, overprovision: int = 1
                          ) -> List[Finding]:
    """Budget-window sanity for one transfer call (PC111).

    The datapath clamps everything into range at runtime; these findings
    catch callers whose *intent* cannot be honoured — a raised
    ``active_budget`` that silently clips back to ``budget``, a window
    that guarantees spill, a zero-lane budget.
    """
    out: List[Finding] = []
    where = "transfer-window"
    if budget < 1:
        out.append(Finding(
            "PC111", f"budget {budget} < 1: every request spills", path=where))
        return out
    if overprovision < 1:
        out.append(Finding(
            "PC111", f"overprovision {overprovision} < 1 (clamps to 1)",
            path=where, severity=WARNING))
    if active_budget is not None:
        ab = np.asarray(active_budget, np.int64).reshape(-1)
        if (ab < 0).any():
            out.append(Finding(
                "PC111", f"active_budget {ab.tolist()} negative (clamps "
                "to 0: the node transfers nothing)", path=where))
        if (ab > budget).any():
            out.append(Finding(
                "PC111", f"active_budget {ab.tolist()} above the static "
                f"budget {budget}: the datapath clamps it back — raising "
                "throughput needs a recompile with a larger budget",
                path=where))
        # Guaranteed spill is a warning: the rate limiter throttles by
        # design, but a caller should know the window cannot fit.
        rounds = -(-num_requests // budget) * max(overprovision, 1)
        short = ab[(ab >= 0) & (ab <= budget)]
        if num_requests > 0 and short.size and \
                int(short.min()) * rounds < num_requests:
            out.append(Finding(
                "PC111", f"window rounds({rounds}) x active_budget"
                f"({int(short.min())}) < {num_requests} requests: the tail "
                "spills every round", path=where, severity=WARNING))
    return out


def verify_program(program, topology=None, *,
                   required_pairs: Optional[np.ndarray] = None) -> None:
    """Raise :class:`ProgramVerificationError` unless the program checks
    clean (warnings do not gate)."""
    from repro.analysis.findings import ProgramVerificationError, errors

    bad = errors(check_program(program, topology,
                               required_pairs=required_pairs))
    if bad:
        raise ProgramVerificationError(bad)

