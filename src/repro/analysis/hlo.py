"""Shared HLO-text parser: computations, call graph, trip counts.

Extracted from ``benchmarks/hlo_analysis.py`` so the FLOPs/HBM analyzer
(the benchmark) and the datapath auditor (:mod:`repro.analysis.jaxpr_audit`)
read one grammar.  Pure stdlib — importable without jax, so schema checks
(``benchmarks/validate_bench.py``) and the lint CLI stay light.

The parser is deliberately line-oriented and regex-based: XLA's HLO text
dump is stable enough for counting (opcodes, shapes, call attributes,
``known_trip_count`` backend configs) and a real grammar would chase a
moving target.  Anything that does not match is skipped, never fatal.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")
SKIP_HBM_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                "bitcast", "while", "call", "conditional", "copy-start",
                "copy-done", "after-all", "partition-id", "replica-id",
                "iota"}

_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
    r"((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"([\w\-]+)\(")
_OPERAND = re.compile(r"%([\w\.\-]+)")
_CALL_ATTR = re.compile(
    r"(?:body|condition|calls|to_apply)=%?([\w\.\-]+)"
    r"|branch_computations=\{([^}]*)\}")
_TRIP = re.compile(r'known_trip_count[^0-9]*?"n":"(\d+)"')


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in SHAPE_RE.findall(shape_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def shape_elems(shape_str: str) -> int:
    m = SHAPE_RE.search(shape_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclass
class Instruction:
    name: str
    opcode: str
    result_shape: str
    result_bytes: int
    operands: list
    raw: str


@dataclass
class Computation:
    name: str
    instructions: list = field(default_factory=list)
    defs: dict = field(default_factory=dict)   # name -> shape string
    is_fused: bool = False

    def hbm_traffic(self) -> float:
        """Estimated real HBM bytes for one execution of this computation
        as a *fusion body*: params are reads (slice-aware), root is the
        write (update-aware for DUS roots)."""
        consumers: dict[str, list] = {}
        for ins in self.instructions:
            for op in ins.operands:
                consumers.setdefault(op, []).append(ins)
        total = 0.0
        root = self.instructions[-1] if self.instructions else None
        for ins in self.instructions:
            if ins.opcode != "parameter":
                continue
            users = consumers.get(ins.name, [])
            if users and all(u.opcode in ("dynamic-slice", "gather")
                             and u.operands and u.operands[0] == ins.name
                             for u in users):
                total += sum(u.result_bytes for u in users)
            elif users and all(
                    u.opcode == "dynamic-update-slice"
                    and u.operands and u.operands[0] == ins.name
                    for u in users):
                # buffer param of an in-place DUS: traffic = update bytes
                total += sum(shape_bytes(self.defs.get(u.operands[1], ""))
                             for u in users)
            else:
                total += shape_bytes(self.defs.get(ins.name, ""))
        if root is not None:
            if root.opcode == "dynamic-update-slice" and len(root.operands) > 1:
                total += shape_bytes(self.defs.get(root.operands[1], ""))
            else:
                total += root.result_bytes
        return total


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if line and not line[0].isspace() and line.rstrip().endswith("{"):
            hm = _COMP_HEADER.match(line)
            if hm:
                is_entry, name = hm.group(1), hm.group(2)
                cur = Computation(name="ENTRY" if is_entry else name)
                comps[cur.name] = cur
                continue
        if cur is None:
            continue
        im = _INSTR.match(line)
        if not im:
            continue
        name, shape_str, opcode = im.groups()
        rest = line[im.end():]
        # operands: %refs before attribute section (first "), " or ")," )
        head = rest.split("),")[0] if ")," in rest else rest
        opnames = [m.group(1) for m in _OPERAND.finditer(head)]
        instr = Instruction(name=name, opcode=opcode, result_shape=shape_str,
                            result_bytes=shape_bytes(shape_str),
                            operands=opnames, raw=line)
        cur.defs[name] = shape_str
        cur.instructions.append(instr)
    return comps


def instruction_callees(ins: Instruction) -> list:
    """Computation names an instruction calls (while/fusion/call/cond)."""
    callees = []
    for cm in _CALL_ATTR.finditer(ins.raw):
        single, multi = cm.groups()
        if single:
            callees.append(single)
        elif multi:
            callees += [s.strip().lstrip("%") for s in multi.split(",")]
    return callees


def while_trip_count(ins: Instruction) -> Optional[float]:
    """Trip count XLA recorded for a counted while, else None."""
    tm = _TRIP.search(ins.raw)
    return float(tm.group(1)) if tm else None


def call_multipliers(comps: Dict[str, Computation]
                     ) -> Tuple[Dict[str, float], int]:
    """Execution-count multiplier per computation from the call graph.

    Fix-point over while / fusion / call / conditional edges starting at
    ENTRY with multiplier 1; a while body's multiplier is scaled by the
    ``known_trip_count`` XLA attached to the loop.  Marks fusion-called
    computations (``is_fused = True``) as a side effect — their HBM
    traffic is accounted at the fusion op, not instruction by instruction.

    Returns ``(multipliers, unknown_trip_counts)`` where the second item
    counts *reachable* while instructions XLA left uncounted (each such
    loop's body is under-multiplied; callers surface it as a confidence
    caveat).
    """
    mult: Dict[str, float] = {}
    if not comps:
        return mult, 0
    entry = comps.get("ENTRY") or next(iter(comps.values()))
    mult[entry.name] = 1.0
    changed, iters = True, 0
    while changed and iters < 100:
        changed, iters = False, iters + 1
        for cname, comp in comps.items():
            base = mult.get(cname, 0.0)
            if base == 0.0:
                continue
            for ins in comp.instructions:
                trips = 1.0
                if ins.opcode == "while":
                    t = while_trip_count(ins)
                    if t is not None:
                        trips = t
                for cn in instruction_callees(ins):
                    if cn not in comps:
                        continue
                    factor = trips if ins.opcode == "while" else 1.0
                    newv = base * factor
                    if mult.get(cn, 0.0) < newv:
                        mult[cn] = newv
                        changed = True
                if ins.opcode == "fusion":
                    for cm in re.finditer(r"calls=%?([\w\.\-]+)", ins.raw):
                        if cm.group(1) in comps:
                            comps[cm.group(1)].is_fused = True
    unknown = sum(
        1 for cname, comp in comps.items() if mult.get(cname, 0.0) > 0.0
        for ins in comp.instructions
        if ins.opcode == "while" and while_trip_count(ins) is None)
    return mult, unknown


def count_ops(text: str, opcode: str) -> int:
    """Count instructions whose opcode starts with ``opcode``, across every
    computation (fusion bodies included).  Used by the bench suite to flag
    intermediate ``copy`` ops and collective counts in lowered datapaths."""
    comps = parse_hlo(text)
    return sum(1 for comp in comps.values() for ins in comp.instructions
               if ins.opcode.startswith(opcode))


_SCOPE_TEMPLATE = r'op_name="[^"]*{prefix}[:_]([A-Za-z0-9_]+)'


def scope_op_counts(hlo_text: str, prefix: str = "obs") -> Dict[str, int]:
    """Count HLO instructions per ``<prefix>:<name>`` named scope.

    The datapath wraps its phases in ``jax.named_scope("obs:wire_req")``
    etc.; after lowering, each instruction's metadata ``op_name`` carries
    the scope path (XLA may rewrite ``:`` to ``_``, so both spellings
    match).  This is the library form of ``obs.trace.phase_op_counts``.
    """
    counts: Dict[str, int] = {}
    for m in re.finditer(_SCOPE_TEMPLATE.format(prefix=re.escape(prefix)),
                         hlo_text):
        counts[m.group(1)] = counts.get(m.group(1), 0) + 1
    return counts
