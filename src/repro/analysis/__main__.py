"""bridgelint CLI: ``python -m repro.analysis [options] [paths...]``.

Runs the AST lint over every ``.py`` under the given paths (default:
the repo's ``src/`` tree) and, unless ``--no-programs``, statically
verifies every shipped steering constructor over a spread of ring sizes
and fabrics — so CI fails the moment a constructor change breaks a
schedule invariant, before any test executes a datapath.

Exit status: 0 when no error-severity findings, 1 otherwise (warnings
print but do not gate).  ``--fix-report out.json`` writes the structured
finding list for tooling.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import List

from repro.analysis.findings import Finding, errors
from repro.analysis.lint import iter_py_files, lint_paths


def _program_self_check() -> List[Finding]:
    """Verify every shipped steering constructor compiles clean programs.

    Imports jax lazily: the lint half of the CLI must work in jax-free
    environments, and a missing jax downgrades this half to a warning.
    """
    try:
        from repro.core import steering
        from repro.core.topology import Topology
    except Exception as e:  # jax absent / broken: report, don't crash
        return [Finding("PC100", f"program self-check skipped: {e}",
                        path="self-check", severity="warning")]
    from repro.analysis.program_check import check_program

    out: List[Finding] = []

    def run(label, program, topology=None):
        for f in check_program(program, topology):
            out.append(Finding(f.rule, f"[{label}] {f.message}",
                               path="self-check", severity=f.severity))

    for n in (2, 3, 5, 8):
        run(f"unidirectional+{n}", steering.unidirectional_program(n))
        run(f"unidirectional-{n}",
            steering.unidirectional_program(n, direction=-1))
        run(f"bidirectional{n}", steering.bidirectional_program(n))
        run(f"link_avoiding{n}", steering.link_avoiding_program(n, 1))
        base = steering.bidirectional_program(n)
        run(f"pruned{n}", steering.pruned_program(base, [1]))
        weights = [float((k % 3) > 0) for k in range(n - 1)]
        if not any(weights):
            weights[0] = 1.0
        run(f"load_balanced{n}",
            steering.load_balanced_program(n, weights))
    for sizes in ([4, 4], [2, 3, 3], [2, 2, 4]):
        topo = Topology.from_sizes(sizes)
        run(f"hierarchical{sizes}", steering.hierarchical_program(topo),
            topo)
        full = steering.hierarchical_program(topo)
        n = topo.num_nodes
        mask = [[(k + r) % 3 != 0 for r in range(n)] for k in range(n - 1)]
        run(f"masked{sizes}", steering.masked_ranks_program(full, mask),
            topo)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="bridgelint: static datapath-contract verification")
    ap.add_argument("paths", nargs="*", default=[],
                    help="files/directories to lint (default: src/)")
    ap.add_argument("--fix-report", metavar="FILE",
                    help="write the structured finding list as JSON")
    ap.add_argument("--no-programs", action="store_true",
                    help="skip the steering-constructor self check")
    args = ap.parse_args(argv)

    paths = args.paths
    if not paths:
        root = pathlib.Path(__file__).resolve().parents[2]
        paths = [str(root)]

    findings = lint_paths(paths)
    if not args.no_programs:
        findings += _program_self_check()

    for f in findings:
        print(str(f))
    bad = errors(findings)
    nfiles = len(iter_py_files(paths))
    print(f"bridgelint: {nfiles} files, {len(findings)} finding(s), "
          f"{len(bad)} error(s)")

    if args.fix_report:
        report = {
            "tool": "bridgelint",
            "paths": [str(p) for p in paths],
            "files": nfiles,
            "errors": len(bad),
            "findings": [f.as_dict() for f in findings],
        }
        pathlib.Path(args.fix_report).write_text(
            json.dumps(report, indent=1, sort_keys=True) + "\n")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
