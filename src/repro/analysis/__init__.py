"""bridgelint — static verification of the bridge's datapath contracts.

The paper's software-defined control plane may reprogram the bridge at
runtime only because a set of invariants holds *statically*: route
programs have fixed shapes (zero retrace on swaps), FREE masks conserve
the live set, gateway epochs are exclusive, and the jitted datapath is
pure (no host sync).  This package turns those test-time invariants into
machine-checked contracts:

  :mod:`repro.analysis.program_check`  RouteProgram/Topology verifier
      (pure numpy; gates ``ControlPlane.route_program`` behind
      ``verify=True``)
  :mod:`repro.analysis.jaxpr_audit`    jaxpr/HLO purity + retrace audit,
      per-channel-depth collective budgets
  :mod:`repro.analysis.lint`           AST lint over ``src/`` for retrace
      hazards and host-side batcher hazards
  :mod:`repro.analysis.hlo`            shared HLO text parser (also used
      by ``benchmarks/hlo_analysis.py``)

CLI (the blocking CI lint job)::

    python -m repro.analysis [--fix-report report.json] src/

Rule ids are stable (``RULES.md``); suppress a lint line with
``# bridgelint: ignore[BL203]``.
"""
from repro.analysis.findings import (ERROR, WARNING, Finding,  # noqa: F401
                                     ProgramVerificationError, errors)
from repro.analysis.program_check import (check_program,  # noqa: F401
                                          check_transfer_window, coverage,
                                          verify_program)
