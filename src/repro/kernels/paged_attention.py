"""Paged decode attention as a Pallas TPU kernel (the bridge's compute hot
spot).

One new token per sequence attends over its KV pages resident in the pooled
cache.  The page table (logical page -> pool slot) is a **scalar-prefetch**
operand: the TPU grid pipeline reads it to steer each step's HBM->VMEM DMA
to the right pool slot — the memport table in hardware, exactly the paper's
"request preparation & steering unit" fused into the kernel's DMA engine.

  grid = (B, P)   — pages of one sequence iterate innermost with (m, l, acc)
  carried in VMEM scratch; invalid / out-of-range pages are masked, the last
  page normalizes and writes [H, hd] out.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import resolve_interpret

NEG_INF = -1e30


def _paged_kernel(table_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
                  m_sc, l_sc, acc_sc, *, page_tokens: int, max_pages: int,
                  num_heads: int, kv_heads: int):
    b = pl.program_id(0)
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    g = num_heads // kv_heads
    hd = q_ref.shape[-1]
    q = q_ref[0].astype(jnp.float32)                    # [H, hd]
    k = k_ref[0].astype(jnp.float32)                    # [T, kv, hd]
    v = v_ref[0].astype(jnp.float32)

    length = lengths_ref[b]
    pos = p * page_tokens + jax.lax.broadcasted_iota(
        jnp.int32, (page_tokens,), 0)
    # only fully-flushed pooled pages participate (the tail lives in the
    # local write buffer and is merged by the caller)
    flushed = (length // page_tokens) * page_tokens
    valid = pos < flushed                               # [T]

    qg = q.reshape(kv_heads, g, hd)
    s = jnp.einsum("kgd,tkd->kgt", qg, k,
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    s = jnp.where(valid[None, None, :], s, NEG_INF)
    s = s.reshape(num_heads, page_tokens)

    m_prev = m_sc[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    pexp = jnp.exp(s - m_new[:, None])
    pexp = jnp.where(valid[None, :], pexp, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_sc[...] = l_sc[...] * alpha + jnp.sum(pexp, axis=1)
    pv = jnp.einsum("ht,tkd->hkd", pexp.reshape(num_heads, page_tokens), v,
                    preferred_element_type=jnp.float32)
    # fold kv dim: head h reads kv head h // g
    pv = pv.reshape(kv_heads, g, kv_heads, hd)
    eye = (jax.lax.broadcasted_iota(jnp.int32, (kv_heads, kv_heads), 0)
           == jax.lax.broadcasted_iota(jnp.int32, (kv_heads, kv_heads), 1))
    pv = jnp.einsum("kgjd,kj->kgd", pv, eye.astype(jnp.float32))
    acc_sc[...] = acc_sc[...] * alpha[:, None] \
        + pv.reshape(num_heads, hd)
    m_sc[...] = m_new

    @pl.when(p == max_pages - 1)
    def _finalize():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0] = (acc_sc[...] / l[:, None]).astype(o_ref.dtype)


def paged_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                    page_table: jax.Array, lengths: jax.Array, *,
                    max_pages: int,
                    interpret: bool | None = None) -> jax.Array:
    """Decode attention over pooled pages.

    q: [B, H, hd]; k_pool/v_pool: [slots, T, kv, hd];
    page_table: i32[B, max_pages] pool slot of each page (-1 = unmapped);
    lengths: i32[B] visible tokens.  -> [B, H, hd]
    """
    b, h, hd = q.shape
    slots, t, kv, _ = k_pool.shape
    table = jnp.where(page_table >= 0, page_table, 0).astype(jnp.int32)

    kernel = functools.partial(
        _paged_kernel, page_tokens=t, max_pages=max_pages, num_heads=h,
        kv_heads=kv)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, max_pages),
        in_specs=[
            pl.BlockSpec((1, h, hd), lambda bi, pi, tbl, ln: (bi, 0, 0)),
            pl.BlockSpec((1, t, kv, hd),
                         lambda bi, pi, tbl, ln: (tbl[bi, pi], 0, 0, 0)),
            pl.BlockSpec((1, t, kv, hd),
                         lambda bi, pi, tbl, ln: (tbl[bi, pi], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, hd), lambda bi, pi, tbl, ln: (bi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((h,), jnp.float32),
            pltpu.VMEM((h,), jnp.float32),
            pltpu.VMEM((h, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, hd), q.dtype),
        interpret=resolve_interpret(interpret),
    )(table, lengths.astype(jnp.int32), q, k_pool, v_pool)
    return out
