"""Flash attention (forward) as a Pallas TPU kernel.

Blockwise online-softmax attention with explicit VMEM tiling:

  grid = (B, H, Sq/bq, Sk/bk)   — the Sk axis iterates innermost, carrying
  (m, l, acc) in VMEM scratch; the final Sk step normalizes and writes out.

Tiling follows MXU alignment: bq and bk default to 128/512, head_dim is the
lane dimension.  Supports GQA (kv_heads <= heads), causal and sliding-window
masks with absolute positions (q_offset) — the same contract as the XLA
reference ``repro.models.flash.attention_ref`` (the oracle for these tests).

Note on TPU adaptation (DESIGN.md §2): the GPU flash algorithm tiles for
shared memory per SM; here blocks are sized for VMEM (~16 MiB/core) and the
MXU's 128x128 systolic shape, and the "parallel over blocks" dimension is
the sequential grid walk of one core rather than a thread block swarm.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import resolve_interpret

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
                      bq: int, bk: int, causal: bool, window: int,
                      q_offset: int, sk_valid: int, num_kb: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0, :, 0, :].astype(jnp.float32)          # [bq, hd]
    k = k_ref[0, :, 0, :].astype(jnp.float32)          # [bk, hd]
    v = v_ref[0, :, 0, :].astype(jnp.float32)          # [bk, hd]
    hd = q.shape[-1]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * (hd ** -0.5)                               # [bq, bk]

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
        + q_offset
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < sk_valid
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_sc[...]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_sc[...] = l_sc[...] * alpha + jnp.sum(p, axis=1)
    acc_sc[...] = acc_sc[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_sc[...] = m_new

    @pl.when(ki == num_kb - 1)
    def _finalize():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_sc[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0, q_offset: int = 0,
                    bq: int = 128, bk: int = 512,
                    interpret: bool | None = None) -> jax.Array:
    """q: [B, Sq, H, hd]; k, v: [B, Sk, kv, hd] -> [B, Sq, H, hd]."""
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    bq = min(bq, sq)
    bk = min(bk, sk)
    sq_pad = (-sq) % bq
    sk_pad = (-sk) % bk
    if sq_pad:
        q = jnp.pad(q, ((0, 0), (0, sq_pad), (0, 0), (0, 0)))
    if sk_pad:
        k = jnp.pad(k, ((0, 0), (0, sk_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_pad), (0, 0), (0, 0)))
    num_qb = q.shape[1] // bq
    num_kb = k.shape[1] // bk
    grid = (b, h, num_qb, num_kb)

    q_spec = pl.BlockSpec((1, bq, 1, hd), lambda bi, hi, qi, ki: (bi, qi, hi, 0))
    k_spec = pl.BlockSpec((1, bk, 1, hd),
                          lambda bi, hi, qi, ki: (bi, ki, hi // g, 0))
    o_spec = pl.BlockSpec((1, bq, 1, hd), lambda bi, hi, qi, ki: (bi, qi, hi, 0))

    kernel = functools.partial(
        _flash_fwd_kernel, bq=bq, bk=bk, causal=causal, window=window,
        q_offset=q_offset, sk_valid=sk, num_kb=num_kb)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[q_spec, k_spec, k_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(q, k, v)
    return out[:, :sq]
