"""Shared Pallas execution-mode policy for every kernel in the repo.

All kernels run through the Pallas interpreter off-TPU (this container is
CPU-only; interpret mode executes the kernel grid as traced jax ops, so
tier-1 stays bit-faithful to the TPU kernel semantics) and compile natively
on real TPU backends.  Historically each kernel wrapper re-derived this
policy by convention; :func:`default_interpret` is the single shared source
of truth.

The environment variable ``REPRO_PALLAS_INTERPRET`` overrides the backend
autodetection in both directions (``1/true/yes/on`` forces interpret mode,
``0/false/no/off`` forces native compilation) — useful to smoke-test the
native lowering path from CI without editing call sites.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

_TRUE = frozenset(("1", "true", "yes", "on"))
_FALSE = frozenset(("0", "false", "no", "off"))

ENV_VAR = "REPRO_PALLAS_INTERPRET"


def default_interpret() -> bool:
    """Interpret-mode default: env override first, then backend detection."""
    env = os.environ.get(ENV_VAR, "").strip().lower()
    if env in _TRUE:
        return True
    if env in _FALSE:
        return False
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Resolve an ``interpret=None`` kernel argument to the shared default."""
    return default_interpret() if interpret is None else bool(interpret)
