"""Fused bridge datapath kernels: serve/steer -> page gather -> commit.

The unfused bridge engine runs every epoch as a chain of discrete XLA ops —
per-slot serve masking, a ``dynamic-slice`` gather per circuit slot, and a
``where``-merge (pull) or scatter (push) per slot — materializing an
intermediate per step.  These Pallas kernels collapse each side of the wire
into **one** ``pallas_call`` walking the pool block-by-block, exactly the
paper's transceiver datapath where the request preparation & steering unit
programs the DMA engine and the payload moves in a single steered
transaction:

* the serve condition (RouteProgram group/FREE masking, loopback vs circuit
  steering) is evaluated into **scalar-prefetch** operands — the memport
  lookup result that steers each grid step's pool DMA, as in
  :mod:`repro.kernels.paged_attention`;
* :func:`gather_pages` serves every landed request of an epoch in one grid
  (FREE requests produce zero flits);
* :func:`pull_commit` retires an epoch on the requester side: the epoch-0
  loopback gather from the local shard and the returned circuit payloads
  commit into the output in one grid — no per-slot ``where`` chain;
* :func:`push_commit` / :func:`scatter_pages` retire the write path on the
  serving side with the pool buffer **donated** (``input_output_aliases``):
  the grid scatters payloads in the serial engine's commit order (sequential
  grid => later writes win, matching the oracle), and FREE lanes are steered
  into a sacrificial pad row — the kernel equivalent of the unfused path's
  ``mode="drop"`` scatter.

All kernels flatten page contents to one trailing dim (pages move as whole
flits; their internal layout is irrelevant to the datapath) and run through
the shared interpret-mode policy in :mod:`repro.kernels.pallas_compat` so
tier-1 executes them off-TPU.  Off-TPU the wrappers do NOT run the generic
Pallas interpreter: it re-materializes the full output (and every carried
buffer) once per grid step, which at 256 KiB pages costs more than the wire
traffic it steers.  Instead each wrapper executes the identical block
program as vectorized ``lax`` ops — same steering, same masked fetches,
same sequential-grid write order (scatter shadowing is resolved explicitly,
so duplicate commits stay deterministic) — keeping tier-1 bit-faithful to
the TPU kernels at datapath speed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import resolve_interpret


def _flatten_pages(pool: jax.Array):
    """[slots, *page_shape] -> ([slots, E], page_shape)."""
    page_shape = pool.shape[1:]
    e = int(np.prod(page_shape)) if page_shape else 1
    return pool.reshape(pool.shape[0], e), page_shape, e


def _obs_scope(name: str):
    """Tag a kernel entry point's ops with an ``obs:<phase>`` named scope.

    The scope lands in HLO metadata ``op_name``, so
    :func:`repro.obs.trace.phase_op_counts` attributes a compiled
    program's instructions (and their dispatch cost) to datapath phases
    even when the caller forgot its own scope.
    """
    def deco(fn):
        @functools.wraps(fn)
        def wrapped(*args, **kwargs):
            with jax.named_scope(name):
                return fn(*args, **kwargs)
        return wrapped
    return deco


# ---------------------------------------------------------------------------
# Pull side
# ---------------------------------------------------------------------------

def _gather_kernel(req_ref, pool_ref, out_ref):
    w = pl.program_id(0)
    valid = req_ref[w] >= 0
    out_ref[0] = jnp.where(valid, pool_ref[0], jnp.zeros_like(pool_ref[0]))


def _gather_pages_lax(pool2: jax.Array, flat: jax.Array) -> jax.Array:
    """Off-TPU gather grid: one clamped row fetch + FREE zero-mask."""
    page = pool2[jnp.maximum(flat, 0)]
    return jnp.where((flat >= 0)[:, None], page, jnp.zeros((), pool2.dtype))


@_obs_scope("obs:gather")
def gather_pages(pool: jax.Array, reqs: jax.Array, *,
                 interpret=None) -> jax.Array:
    """Serve an epoch's landed requests in one kernel.

    pool: [slots, *page_shape]; reqs: i32[...] pool rows (FREE < 0).
    Returns reqs.shape + page_shape — ``pool[req]`` per lane, zeros for FREE
    lanes.  The request ids are a scalar-prefetch operand steering each grid
    step's pool DMA (FREE lanes are clamped to row 0 for the fetch and
    zero-masked in the kernel body).
    """
    pool2, page_shape, e = _flatten_pages(pool)
    shape = reqs.shape
    flat = reqs.reshape(-1).astype(jnp.int32)
    w = flat.shape[0]
    if w == 0:
        return jnp.zeros(shape + page_shape, pool.dtype)
    if resolve_interpret(interpret):
        return _gather_pages_lax(pool2, flat).reshape(shape + page_shape)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(w,),
        in_specs=[
            pl.BlockSpec((1, e), lambda i, rq: (jnp.maximum(rq[i], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, e), lambda i, rq: (i, 0)),
    )
    out = pl.pallas_call(
        _gather_kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((w, e), pool.dtype),
        interpret=resolve_interpret(interpret),
    )(flat, pool2)
    return out.reshape(shape + page_shape)


def _pull_commit_kernel(choice_ref, loop_ref, pool_ref, pay_ref, out_ref):
    i = pl.program_id(0)
    c = choice_ref[i]
    loop_ok = loop_ref[i] >= 0
    zero = jnp.zeros_like(pool_ref[0])
    local = jnp.where(loop_ok, pool_ref[0], zero)
    page = jnp.where(c >= 1, pay_ref[0, 0], local)
    out_ref[0] = jnp.where(c >= 0, page, zero)


def _pull_commit_lax(pool2, pay2, choice, loop_slot) -> jax.Array:
    """Off-TPU commit grid: per-lane source select as three masked fetches."""
    s = pay2.shape[0]
    local = _gather_pages_lax(pool2, loop_slot)
    sel = jnp.clip(choice - 1, 0, s - 1)
    circ = jnp.take_along_axis(pay2, sel[None, :, None], axis=0)[0]
    page = jnp.where((choice >= 1)[:, None], circ, local)
    return jnp.where((choice >= 0)[:, None], page, jnp.zeros((), pool2.dtype))


@_obs_scope("obs:commit")
def pull_commit(pool: jax.Array, payloads: jax.Array, choice: jax.Array,
                loop_slot: jax.Array, *, interpret=None) -> jax.Array:
    """Retire a pull epoch: loopback gather + payload commit in one kernel.

    pool: [slots, *page_shape] (local shard, read-only);
    payloads: [S, L, *page_shape] returned circuit flits (slot-major);
    choice: i32[L] per-lane serving source — ``-1`` dead (zeros), ``0``
    epoch-0 loopback (gather ``pool[loop_slot]``), ``k+1`` circuit slot k;
    loop_slot: i32[L] local pool row for loopback lanes (FREE elsewhere).
    Returns [L, *page_shape].
    """
    pool2, page_shape, e = _flatten_pages(pool)
    s = payloads.shape[0]
    lanes = choice.shape[0]
    pay2 = payloads.reshape(s, lanes, e)
    if resolve_interpret(interpret):
        out = _pull_commit_lax(pool2, pay2, choice.astype(jnp.int32),
                               loop_slot.astype(jnp.int32))
        return out.reshape((lanes,) + page_shape)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(lanes,),
        in_specs=[
            pl.BlockSpec((1, e),
                         lambda i, ch, lp: (jnp.maximum(lp[i], 0), 0)),
            pl.BlockSpec((1, 1, e),
                         lambda i, ch, lp: (jnp.clip(ch[i] - 1, 0, s - 1),
                                            i, 0)),
        ],
        out_specs=pl.BlockSpec((1, e), lambda i, ch, lp: (i, 0)),
    )
    out = pl.pallas_call(
        _pull_commit_kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((lanes, e), pool.dtype),
        interpret=resolve_interpret(interpret),
    )(choice.astype(jnp.int32), loop_slot.astype(jnp.int32), pool2, pay2)
    return out.reshape((lanes,) + page_shape)


# ---------------------------------------------------------------------------
# Push side (donated pool)
# ---------------------------------------------------------------------------

def pad_pool(pool: jax.Array) -> jax.Array:
    """Append the sacrificial drop row FREE pushes are steered into."""
    return jnp.concatenate([pool, jnp.zeros_like(pool[:1])], 0)


def _push_commit_kernel(rows_ref, pool_ref, loop_ref, landed_ref, out_ref):
    del rows_ref, pool_ref          # steering only / aliased output init
    k = pl.program_id(1)
    out_ref[0] = jnp.where(k == 0, loop_ref[0],
                           landed_ref[0, 0]).astype(out_ref.dtype)


def _shadow_to(rows: jax.Array, drop_row: int) -> jax.Array:
    """Steer writes shadowed by a later grid step into the drop row.

    The sequential grid's last-write-wins contract made explicit, so the
    off-TPU scatter never leans on XLA's duplicate-index update order
    (officially unspecified).  Quadratic in the round's write count — a few
    dozen lanes — never in page bytes.
    """
    t = jnp.arange(rows.shape[0])
    shadowed = ((rows[None, :] == rows[:, None])
                & (t[None, :] > t[:, None])).any(1)
    return jnp.where(shadowed, drop_row, rows)


def _push_commit_lax(pool_pad: jax.Array, rows: jax.Array,
                     loop_data: jax.Array, landed_data: jax.Array,
                     channels: int, cb: int) -> jax.Array:
    """Off-TPU push grid: shadow-resolve in (c, k, b) grid order, then
    retire every commit row with one in-place scatter per source buffer —
    the landed flits scatter straight from where they arrived, no
    flattened grid-order staging of the payload bytes."""
    s1, lanes = rows.shape
    drop = pool_pad.shape[0] - 1
    # grid step t = (c*s1 + k)*cb + b  ->  slot k, lane = c*cb + b
    t = jnp.arange(channels * s1 * cb)
    k_t = (t // cb) % s1
    lane_t = (t // (s1 * cb)) * cb + t % cb
    flat = _shadow_to(rows[k_t, lane_t], drop)
    # back to [s1, lanes]: with shadowed writes steered to the drop row,
    # every surviving write is the grid's final value, so the per-slot
    # scatters below can run in any order.
    kk, ll = jnp.meshgrid(jnp.arange(s1), jnp.arange(lanes), indexing="ij")
    res = flat[((ll // cb) * s1 + kk) * cb + ll % cb]
    out = pool_pad.at[res[0]].set(loop_data.astype(pool_pad.dtype))
    for k in range(1, s1):
        out = out.at[res[k]].set(landed_data[k - 1].astype(pool_pad.dtype))
    return out


@_obs_scope("obs:commit")
def push_commit(pool_pad: jax.Array, slots_all: jax.Array,
                loop_data: jax.Array, landed_data: jax.Array, *,
                channels: int, cb: int, interpret=None) -> jax.Array:
    """Retire one push round into the (donated) padded pool.

    pool_pad: [slots + 1, E] local shard with the sacrificial drop row
    appended (:func:`pad_pool`); returned updated, buffer aliased.
    slots_all: i32[S + 1, L] commit rows — row 0 the epoch-0 loopback slots,
    row k+1 circuit slot k's landed slots (FREE < 0 drops).
    loop_data: [L, E] local payloads; landed_data: [S, L, E] landed flits.
    L = channels * cb; the grid runs chunk-major, loopback first within each
    chunk — the serial engine's commit order, so duplicate rows resolve
    identically (sequential grid, later write wins).
    """
    slots = pool_pad.shape[0] - 1
    e = pool_pad.shape[1]
    s1 = slots_all.shape[0]
    rows = jnp.where(slots_all >= 0, slots_all, slots).astype(jnp.int32)
    if resolve_interpret(interpret):
        return _push_commit_lax(pool_pad, rows, loop_data, landed_data,
                                channels, cb)

    def row_of(c, k, b, rw):
        return (rw[k, c * cb + b], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(channels, s1, cb),
        in_specs=[
            pl.BlockSpec((1, e), row_of),
            pl.BlockSpec((1, e), lambda c, k, b, rw: (c * cb + b, 0)),
            pl.BlockSpec((1, 1, e),
                         lambda c, k, b, rw: (jnp.maximum(k - 1, 0),
                                              c * cb + b, 0)),
        ],
        out_specs=pl.BlockSpec((1, e), row_of),
    )
    return pl.pallas_call(
        _push_commit_kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool_pad.shape, pool_pad.dtype),
        input_output_aliases={1: 0},
        interpret=resolve_interpret(interpret),
    )(rows, pool_pad, loop_data, landed_data)


def _scatter_kernel(rows_ref, pool_ref, data_ref, out_ref):
    del rows_ref, pool_ref
    out_ref[0] = data_ref[0].astype(out_ref.dtype)


@_obs_scope("obs:commit")
def scatter_pages(pool: jax.Array, slots: jax.Array, data: jax.Array, *,
                  interpret=None) -> jax.Array:
    """One-kernel masked scatter: ``pool.at[slots].set(data, mode="drop")``.

    pool: [slots, *page_shape]; slots: i32[W] (FREE < 0 drops);
    data: [W, *page_shape].  The loopback (1-node) commit path: FREE lanes
    steer into the sacrificial pad row and are trimmed, live duplicates
    resolve last-write-wins (sequential grid).  The padded pool buffer is
    donated to the kernel.
    """
    pool2, page_shape, e = _flatten_pages(pool)
    w = slots.shape[0]
    if w == 0:
        return pool
    nrows = pool2.shape[0]
    rows = jnp.where(slots >= 0, slots, nrows).astype(jnp.int32)
    data2 = data.reshape(w, e)
    if resolve_interpret(interpret):
        out = pad_pool(pool2).at[_shadow_to(rows, nrows)].set(
            data2.astype(pool2.dtype))
        return out[:nrows].reshape(pool.shape)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(w,),
        in_specs=[
            pl.BlockSpec((1, e), lambda i, rw: (rw[i], 0)),
            pl.BlockSpec((1, e), lambda i, rw: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, e), lambda i, rw: (rw[i], 0)),
    )
    out = pl.pallas_call(
        _scatter_kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nrows + 1, e), pool2.dtype),
        input_output_aliases={1: 0},
        interpret=resolve_interpret(interpret),
    )(rows, pad_pool(pool2), data2)
    return out[:nrows].reshape(pool.shape)
