"""STREAM kernels (copy / scale / add / triad) as Pallas TPU kernels.

The paper's evaluation vehicle (§3): STREAM measures sustainable memory
bandwidth as perceived by the application.  On TPU the analogue is HBM->VMEM
streaming through the VPU; these kernels tile 1-D arrays into MXU/VPU-aligned
(rows, 128·k) VMEM blocks and express each STREAM kernel as one grid pass.

Local mode streams HBM directly; "remote" mode (benchmarks) runs the same
kernels against bridge-delivered pages — the byte-for-byte TPU equivalent of
the paper's local-vs-disaggregated comparison.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pallas_compat import resolve_interpret

LANES = 128
SUBLANES = 8
DEFAULT_BLOCK_ROWS = 256  # rows of 128 lanes per VMEM block (128 KiB fp32)


def _copy_kernel(src_ref, dst_ref):
    dst_ref[...] = src_ref[...]


def _scale_kernel(src_ref, dst_ref, *, q):
    dst_ref[...] = (q * src_ref[...].astype(jnp.float32)).astype(dst_ref.dtype)


def _add_kernel(a_ref, b_ref, dst_ref):
    dst_ref[...] = a_ref[...] + b_ref[...]


def _triad_kernel(b_ref, c_ref, dst_ref, *, q):
    acc = b_ref[...].astype(jnp.float32) + q * c_ref[...].astype(jnp.float32)
    dst_ref[...] = acc.astype(dst_ref.dtype)


def _grid_1d(x: jax.Array, block_rows: int):
    n = x.shape[0]
    rows = -(-n // LANES)
    block_rows = min(block_rows, rows)
    rows_pad = -(-rows // block_rows) * block_rows
    flat = x
    if rows_pad * LANES != n:
        flat = jnp.pad(x, (0, rows_pad * LANES - n))
    grid = (rows_pad // block_rows,)
    spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    return flat.reshape(rows_pad, LANES), grid, spec


def _run(kernel, arrays, block_rows: int, interpret):
    n = arrays[0].shape[0]
    shaped = [_grid_1d(a, block_rows) for a in arrays]
    x0, grid, spec = shaped[0]
    ins = [s[0] for s in shaped]
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec] * len(ins),
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x0.shape, x0.dtype),
        interpret=resolve_interpret(interpret),
    )(*ins)
    return out.reshape(-1)[:n]


def stream_copy(c: jax.Array, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                interpret: bool | None = None) -> jax.Array:
    """a[i] = c[i]   (16 B/iter fp32, 0 flops — paper's 'copy')."""
    return _run(_copy_kernel, [c], block_rows, interpret)


def stream_scale(c: jax.Array, q: float, *,
                 block_rows: int = DEFAULT_BLOCK_ROWS,
                 interpret: bool | None = None) -> jax.Array:
    """b[i] = q * c[i]   (16 B/iter, 1 flop — 'scale')."""
    return _run(functools.partial(_scale_kernel, q=q), [c], block_rows,
                interpret)


def stream_add(a: jax.Array, b: jax.Array, *,
               block_rows: int = DEFAULT_BLOCK_ROWS,
               interpret: bool | None = None) -> jax.Array:
    """c[i] = a[i] + b[i]   (24 B/iter, 1 flop — 'add')."""
    return _run(_add_kernel, [a, b], block_rows, interpret)


def stream_triad(b: jax.Array, c: jax.Array, q: float, *,
                 block_rows: int = DEFAULT_BLOCK_ROWS,
                 interpret: bool | None = None) -> jax.Array:
    """a[i] = b[i] + q * c[i]   (24 B/iter, 2 flops — 'triad')."""
    return _run(functools.partial(_triad_kernel, q=q), [b, c], block_rows,
                interpret)
