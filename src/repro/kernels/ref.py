"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.kvbridge import decode_attention_ref
from repro.models.flash import attention_ref


# -- STREAM -------------------------------------------------------------------

def stream_copy_ref(c):
    return jnp.asarray(c)


def stream_scale_ref(c, q):
    return (q * c.astype(jnp.float32)).astype(c.dtype)


def stream_add_ref(a, b):
    return a + b


def stream_triad_ref(b, c, q):
    return (b.astype(jnp.float32)
            + q * c.astype(jnp.float32)).astype(b.dtype)


# -- flash attention ------------------------------------------------------------

def flash_attention_ref(q, k, v, *, causal=True, window=0, q_offset=0):
    return attention_ref(q, k, v, causal=causal, window=window,
                         q_offset=q_offset)


# -- paged decode attention ------------------------------------------------------

def paged_attention_ref(q, k_pool, v_pool, page_table, lengths, *,
                        max_pages: int):
    """Gather pages dense, then masked GQA decode attention over flushed
    pages only (tail handled by the caller, as in the kernel)."""
    b, h, hd = q.shape
    slots, t, kv, _ = k_pool.shape
    safe = jnp.where(page_table >= 0, page_table, 0)
    k = k_pool[safe]                     # [B, P, T, kv, hd]
    v = v_pool[safe]
    k = k.reshape(b, max_pages * t, kv, hd)
    v = v.reshape(b, max_pages * t, kv, hd)
    flushed_tokens = (lengths // t) * t
    return decode_attention_ref(q, k, v, flushed_tokens)
