"""Streaming decode attention over bridge-pulled KV page rounds.

``kvbridge.decode_attention_pull`` historically pulled **every** KV page of
every sequence through the bridge, materialized the full
``[B, max_pages, T, kv, hd]`` buffers, and only then ran the per-page
partial/segment-combine chain.  The fused datapath instead consumes each
round of landed pages **inside the attention grid**: one
:func:`stream_decode_accumulate` call folds a round's ``[W, T, kv, hd]``
flits into the running flash-decode accumulators ``(m, l, acc)``, so the
peak footprint is one round of pages (cut-through: a page is consumed the
moment it lands, never stored).

The kernel is the round-streamed sibling of
:mod:`repro.kernels.paged_attention`: grid ``(B, W)``, per-sequence
``(m, l, acc)`` carried in VMEM scratch across the round's lanes, with the
lane->sequence routing (a scalar-prefetch operand, derived from the landed
logical page ids) steering which grid steps update which sequence.  Only
fully-flushed pages travel through the bridge, so a live lane contributes
all ``T`` tokens — raggedness is handled by the caller's tail partial.

Numerics: float32 online softmax, identical update algebra to the unfused
``_page_partial`` + LSE-combine chain but applied in landing order, so
outputs agree to float tolerance (the pulled pages and telemetry stay
bit-exact — only the accumulation order differs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import resolve_interpret

NEG_INF = -1e30


def _stream_kernel(seq_ref, live_ref, q_ref, k_ref, v_ref,
                   m_in_ref, l_in_ref, o_in_ref,
                   m_out_ref, l_out_ref, o_out_ref,
                   m_sc, l_sc, acc_sc, *, lanes: int, num_heads: int,
                   kv_heads: int):
    b = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _load():
        m_sc[...] = m_in_ref[0]
        l_sc[...] = l_in_ref[0]
        acc_sc[...] = o_in_ref[0]

    @pl.when((seq_ref[i] == b) & (live_ref[i] > 0))
    def _update():
        g = num_heads // kv_heads
        hd = q_ref.shape[-1]
        t = k_ref.shape[1]
        q = q_ref[0].astype(jnp.float32)                 # [H, hd]
        k = k_ref[0].astype(jnp.float32)                 # [T, kv, hd]
        v = v_ref[0].astype(jnp.float32)
        qg = q.reshape(kv_heads, g, hd)
        s = jnp.einsum("kgd,tkd->kgt", qg, k,
                       preferred_element_type=jnp.float32) * (hd ** -0.5)
        s = s.reshape(num_heads, t)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * alpha + jnp.sum(p, axis=1)
        pv = jnp.einsum("kgt,tkd->kgd", p.reshape(kv_heads, g, t), v,
                        preferred_element_type=jnp.float32)
        acc_sc[...] = acc_sc[...] * alpha[:, None] \
            + pv.reshape(num_heads, hd)
        m_sc[...] = m_new

    @pl.when(i == lanes - 1)
    def _store():
        m_out_ref[0] = m_sc[...]
        l_out_ref[0] = l_sc[...]
        o_out_ref[0] = acc_sc[...]


def stream_decode_accumulate(q: jax.Array, k_pages: jax.Array,
                             v_pages: jax.Array, seq_ids: jax.Array,
                             live: jax.Array, m: jax.Array, l: jax.Array,
                             o: jax.Array, *, interpret=None):
    """Fold one landed page round into the flash-decode accumulators.

    q: [B, H, hd] decode queries; k_pages/v_pages: [W, T, kv, hd] this
    round's landed flits; seq_ids: i32[W] owning sequence per lane;
    live: bool/i32[W] lane carries a real page; m, l: f32[B, H];
    o: f32[B, H, hd] running (max, denom, weighted-sum) state.
    Returns the updated ``(m, l, o)``.
    """
    b, h, hd = q.shape
    w, t, kv, _ = k_pages.shape
    if w == 0:
        return m, l, o
    kernel = functools.partial(_stream_kernel, lanes=w, num_heads=h,
                               kv_heads=kv)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, w),
        in_specs=[
            pl.BlockSpec((1, h, hd), lambda bi, i, sq, lv: (bi, 0, 0)),
            pl.BlockSpec((1, t, kv, hd), lambda bi, i, sq, lv: (i, 0, 0, 0)),
            pl.BlockSpec((1, t, kv, hd), lambda bi, i, sq, lv: (i, 0, 0, 0)),
            pl.BlockSpec((1, h), lambda bi, i, sq, lv: (bi, 0)),
            pl.BlockSpec((1, h), lambda bi, i, sq, lv: (bi, 0)),
            pl.BlockSpec((1, h, hd), lambda bi, i, sq, lv: (bi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, h), lambda bi, i, sq, lv: (bi, 0)),
            pl.BlockSpec((1, h), lambda bi, i, sq, lv: (bi, 0)),
            pl.BlockSpec((1, h, hd), lambda bi, i, sq, lv: (bi, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((h,), jnp.float32),
            pltpu.VMEM((h,), jnp.float32),
            pltpu.VMEM((h, hd), jnp.float32),
        ],
    )
    m2, l2, o2 = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, h), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
            jax.ShapeDtypeStruct((b, h, hd), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(seq_ids.astype(jnp.int32), live.astype(jnp.int32),
      q, k_pages, v_pages, m.astype(jnp.float32), l.astype(jnp.float32),
      o.astype(jnp.float32))
    return m2, l2, o2
