"""Jitted public wrappers for the Pallas kernels.

``interpret=None`` resolves through the shared policy in
:mod:`repro.kernels.pallas_compat`: interpret mode off-TPU (this container
is CPU-only; the kernel bodies execute via the Pallas interpreter for
correctness), native compilation on real TPU backends, overridable either
way with ``REPRO_PALLAS_INTERPRET``.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import flash_attention as _flash
from repro.kernels import paged_attention as _paged
from repro.kernels import stream as _stream
from repro.kernels.pallas_compat import default_interpret as _default_interpret


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def stream_copy(c, *, block_rows=_stream.DEFAULT_BLOCK_ROWS, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _stream.stream_copy(c, block_rows=block_rows, interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("q", "block_rows", "interpret"))
def stream_scale(c, q=3.0, *, block_rows=_stream.DEFAULT_BLOCK_ROWS,
                 interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _stream.stream_scale(c, q, block_rows=block_rows,
                                interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def stream_add(a, b, *, block_rows=_stream.DEFAULT_BLOCK_ROWS,
               interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _stream.stream_add(a, b, block_rows=block_rows,
                              interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("q", "block_rows", "interpret"))
def stream_triad(b, c, q=3.0, *, block_rows=_stream.DEFAULT_BLOCK_ROWS,
                 interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _stream.stream_triad(b, c, q, block_rows=block_rows,
                                interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "q_offset", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    bq=128, bk=512, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _flash.flash_attention(q, k, v, causal=causal, window=window,
                                  q_offset=q_offset, bq=bq, bk=bk,
                                  interpret=interpret)


@functools.partial(jax.jit, static_argnames=("max_pages", "interpret"))
def paged_attention(q, k_pool, v_pool, page_table, lengths, *,
                    max_pages, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _paged.paged_attention(q, k_pool, v_pool, page_table, lengths,
                                  max_pages=max_pages, interpret=interpret)
