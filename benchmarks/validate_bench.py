"""Schema + acceptance check for BENCH_bridge.json (CI smoke job).

Run after ``benchmarks/bridge_latency.py``: validates that the emitted
perf record has the expected shape (so the cross-PR trajectory stays
machine-readable) and that the closed control loop held — the
telemetry-compiled load-balanced program predicts a strictly lower round
latency than the static bidirectional split under the measured skew.
"""
from __future__ import annotations

import json
import pathlib
import sys

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_bridge.json"

TOP_KEYS = {"sw_pull_1page_us", "num_nodes", "page_bytes", "budget",
            "variants", "measured"}
VARIANTS = {"unidirectional", "bidirectional", "pruned", "load_balanced"}
VARIANT_KEYS = {"epochs", "live_slots", "total_hops", "bytes_per_round",
                "model_round_us", "model_round_us_bufferless"}
MEASURED_KEYS = {"source", "skew_pages", "distance_pages_per_round",
                 "spilled", "pruned", "static_bidirectional_us",
                 "load_balanced_us"}


def fail(msg: str) -> None:
    print(f"BENCH_bridge.json invalid: {msg}", file=sys.stderr)
    sys.exit(1)


def main() -> None:
    if not BENCH_JSON.exists():
        fail(f"{BENCH_JSON} missing (run benchmarks/bridge_latency.py)")
    bench = json.loads(BENCH_JSON.read_text())
    missing = TOP_KEYS - bench.keys()
    if missing:
        fail(f"missing top-level keys {sorted(missing)}")
    if not VARIANTS <= bench["variants"].keys():
        fail(f"missing variants {sorted(VARIANTS - bench['variants'].keys())}")
    for name, v in bench["variants"].items():
        gone = VARIANT_KEYS - v.keys()
        if gone:
            fail(f"variant {name!r} missing keys {sorted(gone)}")
        bad = [k for k in VARIANT_KEYS if not isinstance(v[k], (int, float))]
        if bad:
            fail(f"variant {name!r} non-numeric keys {bad}")
    m = bench["measured"]
    gone = MEASURED_KEYS - m.keys()
    if gone:
        fail(f"measured section missing keys {sorted(gone)}")
    if len(m["distance_pages_per_round"]) != bench["num_nodes"] - 1:
        fail("distance histogram length != N-1")
    # The acceptance bar: measured steering strictly beats static routing.
    if not m["load_balanced_us"] < m["static_bidirectional_us"]:
        fail(f"load-balanced ({m['load_balanced_us']}us) not below static "
             f"bidirectional ({m['static_bidirectional_us']}us) under the "
             f"measured skew")
    print(f"BENCH_bridge.json ok: {len(bench['variants'])} variants, "
          f"measured {m['source']}: static {m['static_bidirectional_us']}us "
          f"-> load-balanced {m['load_balanced_us']}us")


if __name__ == "__main__":
    main()
