"""Schema + acceptance check for BENCH_bridge.json (CI smoke job).

Run after ``benchmarks/bridge_latency.py``: validates that the emitted
perf record has the expected shape (so the cross-PR trajectory stays
machine-readable) and that the closed-loop acceptance bars held — the
telemetry-compiled load-balanced program predicts a strictly lower round
latency than the static bidirectional split under the measured skew, on
every board + rack fabric the hierarchical schedule strictly beats the
topology-blind flat bidirectional one under intra-board-heavy traffic,
and the orchestrator's QoS windows keep the interactive tenant's
co-located completion latency within 1.5x of its solo run (the isolation
bound) while naive FIFO sharing is strictly worse.

The observability loop adds two more gates: the ``calibration`` section's
RLS-fitted perfmodel constants must predict the measured scenarios with
lower error than the static datasheet prior (per scenario and overall),
and ``BENCH_trace.json`` must be a well-formed Chrome-trace/Perfetto
record of the run's fenced spans.  The ``alerts`` section gates the
anomaly sentinel: zero false-positive alerts on the clean orchestrated
drill, and an injected 2x latency regression flagged within one
detection window.

``BENCH_serve.json`` (from ``benchmarks/serve_bench.py``) gates the
request-level serving front end: continuous batching must be bit-identical
to solo decode on every checked placement, the flood run must simulate at
least ``SERVE_MIN_CONCURRENT`` concurrent sequences with full request
conservation (every non-shed submission completes), per-QoS p50/p99 round
latencies must be present and sane, and under the batch flood the QoS
slot admission must keep the interactive p99 within
``SERVE_ISOLATION_BOUND``x of its solo run while naive FIFO is strictly
worse.
"""
from __future__ import annotations

import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))
from repro.analysis.jaxpr_audit import check_collective_budget  # noqa: E402

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_bridge.json"
TRACE_JSON = BENCH_JSON.with_name("BENCH_trace.json")
SERVE_JSON = BENCH_JSON.with_name("BENCH_serve.json")

TOP_KEYS = {"sw_pull_1page_us", "num_nodes", "page_bytes", "budget",
            "variants", "measured", "hierarchical", "pipeline", "tenancy",
            "fused", "calibration", "alerts"}
ALERT_KEYS = {"source", "window", "clean_rounds", "clean_alerts",
              "regression_alerts", "detect_samples", "alert_kinds"}
VARIANTS = {"unidirectional", "bidirectional", "pruned", "load_balanced"}
VARIANT_KEYS = {"epochs", "live_slots", "total_hops", "bytes_per_round",
                "model_round_us", "model_round_us_bufferless"}
MEASURED_KEYS = {"source", "skew_pages", "distance_pages_per_round",
                 "spilled", "pruned", "static_bidirectional_us",
                 "load_balanced_us"}
HIER_FABRICS = {"8", "16", "32"}
HIER_KEYS = {"source", "num_boards", "board_size", "intra_pages",
             "bytes_per_round", "board_hops_flat", "board_hops_hier",
             "flat_bidirectional_us", "hierarchical_us"}
PIPELINE_KEYS = {"source", "model_round_us", "selected_channels"}
PIPELINE_CHANNELS = {"1", "2", "4", "8"}
PIPELINE_PICKS = {"wire_bound_256KiB", "latency_bound_4KiB"}
TENANCY_KEYS = {"source", "interactive_pages", "batch_backlog_pages",
                "windows", "refit_windows", "interactive_solo_us",
                "interactive_naive_us", "interactive_qos_us",
                "qos_isolation_ratio", "naive_degradation_ratio",
                "tenant_served", "tenant_spilled"}
TENANCY_TENANTS = {"interactive", "batch"}
# The isolation acceptance bound: under batch co-location the QoS scheduler
# must keep the interactive tenant's completion latency within 1.5x of its
# solo run (the naive FIFO composition has no such bound and must be worse).
TENANCY_ISOLATION_BOUND = 1.5
# Measured pipeline-sweep band: with the fused datapath the per-round
# collective count no longer scales with channels, so deeper pipelines may
# cost at most this factor over the serial engine's wall-clock (dispatch
# jitter allowance) — the PR 4 regression was a 3.3x monotonic blow-up.
MEASURED_SWEEP_BAND = 1.35
FUSED_PAGE_SIZES = {"256KiB", "4KiB"}
CAL_FEATURES = ["board_hop_rtts", "rack_hop_rtts", "wire_mib", "chunks",
                "transfers"]
CAL_SAMPLE_KEYS = {"scenario", "name", "features", "measured_us",
                   "static_us", "fitted_us", "static_err", "fitted_err"}
PHASES = {"wire_req", "gather", "wire_data", "commit"}
TRACE_X_KEYS = {"name", "cat", "ph", "ts", "dur", "pid", "tid", "args"}
SERVE_TOP_KEYS = {"source", "config", "fidelity", "scale", "isolation"}
SERVE_SCALE_KEYS = {"num_slots", "arrival_steps", "decode_steps",
                    "submitted", "completed", "shed", "peak_in_flight",
                    "tokens", "goodput_tokens_per_s", "latency_us",
                    "ttft_us", "per_tenant"}
SERVE_ISO_KEYS = {"interactive_requests", "interactive_solo_p99_us",
                  "interactive_qos_p99_us", "interactive_naive_p99_us",
                  "qos_isolation_ratio", "naive_degradation_ratio"}
SERVE_QOS_CLASSES = {"interactive", "batch"}
SERVE_Q_KEYS = {"count", "mean", "p50", "p99"}
# The serve acceptance bars: the flood run must reach real fleet-scale
# concurrency, and QoS slot admission must bound the interactive tenant's
# request p99 under the batch flood (naive FIFO has no bound and must be
# strictly worse — otherwise the policy is isolating nothing).
SERVE_MIN_CONCURRENT = 1000
SERVE_ISOLATION_BOUND = 3.0


def fail(msg: str) -> None:
    print(f"BENCH_bridge.json invalid: {msg}", file=sys.stderr)
    sys.exit(1)


def check_calibration(cal: dict) -> str:
    """The measure->fit->steer gate: fitted constants must beat the static
    datasheet prior on every measured scenario they were fitted from."""
    if cal.get("feature_names") != CAL_FEATURES:
        fail(f"calibration feature_names != {CAL_FEATURES}")
    if "samples" not in cal:
        return f"calibration {cal.get('source', '?')} (model-only)"
    if not cal["samples"]:
        fail("calibration ran on a ring but collected no samples")
    for s in cal["samples"]:
        gone = CAL_SAMPLE_KEYS - s.keys()
        if gone:
            fail(f"calibration sample {s.get('name')!r} missing {sorted(gone)}")
        if len(s["features"]) != len(CAL_FEATURES):
            fail(f"calibration sample {s['name']!r} feature length "
                 f"{len(s['features'])} != {len(CAL_FEATURES)}")
    consts = cal.get("constants", {})
    gone = (set(CAL_FEATURES) | {"link_payload_gbps", "samples"}) - consts.keys()
    if gone:
        fail(f"calibration constants missing {sorted(gone)}")
    err = cal.get("model_vs_measured_error", {})
    scens = {s["scenario"] for s in cal["samples"]} | {"overall"}
    gone = scens - err.keys()
    if gone:
        fail(f"calibration error record missing scenarios {sorted(gone)}")
    for scen in sorted(scens):
        e = err[scen]
        if not isinstance(e.get("static"), (int, float)) or \
                not isinstance(e.get("fitted"), (int, float)):
            fail(f"calibration error for {scen!r} non-numeric: {e}")
        # The acceptance bar: online-fitted constants beat the static prior.
        if not e["fitted"] <= e["static"]:
            fail(f"calibration: fitted error {e['fitted']} above static "
                 f"{e['static']} on {scen!r} — the measure->fit loop is "
                 f"making the model worse")
    picks = cal.get("selected_channels", {})
    for mode in ("static", "calibrated"):
        if mode not in picks:
            fail(f"calibration selected_channels missing {mode!r}")
    o = err["overall"]
    return (f"calibration {cal['source']}: {len(cal['samples'])} samples, "
            f"err {o['static']} -> {o['fitted']}, picks "
            f"{picks['calibrated']}")


def check_alerts(al: dict) -> str:
    """The sentinel drill gate: a clean orchestrated run raises zero
    alerts (false positives page humans at 3am), and an injected 2x
    latency regression is flagged within one detection window."""
    gone = ALERT_KEYS - al.keys()
    if gone:
        fail(f"alerts section missing keys {sorted(gone)}")
    bad = [k for k in ("window", "clean_rounds", "clean_alerts",
                       "regression_alerts", "detect_samples")
           if not isinstance(al[k], int)]
    if bad:
        fail(f"alerts non-integer keys {sorted(bad)}")
    if al["clean_alerts"] != 0:
        fail(f"alerts: {al['clean_alerts']} false-positive alert(s) on the "
             f"clean run ({al['alert_kinds']})")
    if al["regression_alerts"] < 1:
        fail("alerts: the injected 2x latency regression raised no alert")
    if not 0 < al["detect_samples"] <= al["window"]:
        fail(f"alerts: regression detected after {al['detect_samples']} "
             f"samples, outside the {al['window']}-sample window")
    return (f"alerts clean={al['clean_alerts']} detected in "
            f"{al['detect_samples']}/{al['window']} "
            f"({','.join(al['alert_kinds'])})")


def check_phase_breakdown(pb: dict, num_nodes: int) -> None:
    """Per-depth phase attribution of the measured pipeline sweep."""
    for key in ("unfused", "fused", "dispatch_us_per_op",
                "dispatch_base_us", "finding"):
        if key not in pb:
            fail(f"phase_breakdown missing {key!r}")
    if not isinstance(pb["dispatch_us_per_op"], (int, float)):
        fail("phase_breakdown dispatch_us_per_op non-numeric")
    for engine in ("unfused", "fused"):
        gone = PIPELINE_CHANNELS - pb[engine].keys()
        if gone:
            fail(f"phase_breakdown[{engine}] missing depths {sorted(gone)}")
        for c, e in pb[engine].items():
            if not PHASES <= e.get("phase_ops", {}).keys():
                fail(f"phase_breakdown[{engine}][{c}] missing phases "
                     f"{sorted(PHASES - e.get('phase_ops', {}).keys())}")
            if e.get("total_ops") != sum(e["phase_ops"].values()):
                fail(f"phase_breakdown[{engine}][{c}] total_ops does not "
                     f"sum its phase_ops")
    # The attribution evidence itself: the unfused engine's scoped op count
    # must grow with depth while the fused engine's stays flat — that
    # structural difference is the measured regression's cause.
    if not pb["unfused"]["8"]["total_ops"] > pb["unfused"]["1"]["total_ops"]:
        fail("phase_breakdown: unfused op count not growing with depth")
    if pb["fused"]["8"]["phase_ops"]["wire_req"] != \
            pb["fused"]["1"]["phase_ops"]["wire_req"]:
        fail("phase_breakdown: fused wire_req op count scales with depth "
             "(the fused engine should issue one request all_gather)")
    # The jaxpr audit's per-channel-depth collective budget, applied to the
    # recorded counts: unfused serial exactly N-1 wire ops per phase,
    # unfused pipelined at most (N-1)(c+1), fused depth-constant.
    budget_findings = check_collective_budget(pb, num_nodes)
    if budget_findings:
        fail("phase_breakdown violates the jaxpr audit's collective "
             "budget:\n  " + "\n  ".join(str(f) for f in budget_findings))


def check_trace() -> str:
    """BENCH_trace.json must be a loadable Chrome-trace span record."""
    if not TRACE_JSON.exists():
        fail(f"{TRACE_JSON.name} missing (bridge_latency.py writes it)")
    trace = json.loads(TRACE_JSON.read_text())
    events = trace.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail(f"{TRACE_JSON.name}: traceEvents missing or empty")
    if not any(e.get("ph") == "M" for e in events):
        fail(f"{TRACE_JSON.name}: no process_name metadata event")
    xs = [e for e in events if e.get("ph") == "X"]
    if not xs:
        fail(f"{TRACE_JSON.name}: no complete ('X') span events")
    for e in xs:
        gone = TRACE_X_KEYS - e.keys()
        if gone:
            fail(f"{TRACE_JSON.name}: span {e.get('name')!r} missing "
                 f"{sorted(gone)}")
        if e["dur"] < 0:
            fail(f"{TRACE_JSON.name}: span {e['name']!r} negative duration")
    return f"trace {len(xs)} spans"


def check_serve() -> str:
    """BENCH_serve.json: fidelity, fleet-scale concurrency, QoS isolation."""
    if not SERVE_JSON.exists():
        fail(f"{SERVE_JSON.name} missing (run benchmarks/serve_bench.py)")
    serve = json.loads(SERVE_JSON.read_text())
    gone = SERVE_TOP_KEYS - serve.keys()
    if gone:
        fail(f"{SERVE_JSON.name}: missing top-level keys {sorted(gone)}")
    fid = serve["fidelity"]
    if not fid.get("placements"):
        fail(f"{SERVE_JSON.name}: fidelity checked no placements")
    for kv, p in fid["placements"].items():
        if p.get("completed", 0) <= 0 or p.get("matched") != p["completed"]:
            fail(f"{SERVE_JSON.name}: fidelity[{kv}] "
                 f"{p.get('matched')}/{p.get('completed')} matched")
    if fid.get("bit_identical") is not True:
        fail(f"{SERVE_JSON.name}: continuous batching is not bit-identical "
             f"to solo decode")
    sc = serve["scale"]
    gone = SERVE_SCALE_KEYS - sc.keys()
    if gone:
        fail(f"{SERVE_JSON.name}: scale missing keys {sorted(gone)}")
    if sc["peak_in_flight"] < SERVE_MIN_CONCURRENT:
        fail(f"{SERVE_JSON.name}: peak in-flight {sc['peak_in_flight']} "
             f"below the {SERVE_MIN_CONCURRENT}-sequence scale bar")
    if sc["completed"] + sc["shed"] != sc["submitted"]:
        fail(f"{SERVE_JSON.name}: request conservation broken — "
             f"{sc['completed']} completed + {sc['shed']} shed != "
             f"{sc['submitted']} submitted")
    if sc["completed"] <= 0 or not sc["goodput_tokens_per_s"] > 0:
        fail(f"{SERVE_JSON.name}: flood run completed nothing")
    for fam in ("latency_us", "ttft_us"):
        gone = SERVE_QOS_CLASSES - sc[fam].keys()
        if gone:
            fail(f"{SERVE_JSON.name}: {fam} missing QoS classes "
                 f"{sorted(gone)}")
        for qos, q in sc[fam].items():
            gone = SERVE_Q_KEYS - q.keys()
            if gone:
                fail(f"{SERVE_JSON.name}: {fam}[{qos}] missing "
                     f"{sorted(gone)}")
            bad = [k for k in SERVE_Q_KEYS
                   if not isinstance(q[k], (int, float))]
            if bad:
                fail(f"{SERVE_JSON.name}: {fam}[{qos}] non-numeric {bad}")
            if q["count"] <= 0 or q["p50"] > q["p99"]:
                fail(f"{SERVE_JSON.name}: {fam}[{qos}] degenerate "
                     f"quantiles {q}")
    iso = serve["isolation"]
    gone = SERVE_ISO_KEYS - iso.keys()
    if gone:
        fail(f"{SERVE_JSON.name}: isolation missing keys {sorted(gone)}")
    bad = [k for k in SERVE_ISO_KEYS if not isinstance(iso[k], (int, float))]
    if bad:
        fail(f"{SERVE_JSON.name}: isolation non-numeric keys {sorted(bad)}")
    if not iso["qos_isolation_ratio"] <= SERVE_ISOLATION_BOUND:
        fail(f"{SERVE_JSON.name}: interactive p99 under flood is "
             f"{iso['qos_isolation_ratio']}x solo, above the "
             f"{SERVE_ISOLATION_BOUND}x bound")
    if not iso["naive_degradation_ratio"] > iso["qos_isolation_ratio"]:
        fail(f"{SERVE_JSON.name}: naive FIFO "
             f"({iso['naive_degradation_ratio']}x) not worse than QoS "
             f"({iso['qos_isolation_ratio']}x) — slot admission is "
             f"isolating nothing")
    return (f"serve {sc['peak_in_flight']} peak in-flight, "
            f"{sc['completed']}/{sc['submitted']} completed, qos "
            f"x{iso['qos_isolation_ratio']} vs naive "
            f"x{iso['naive_degradation_ratio']}")


def main() -> None:
    if not BENCH_JSON.exists():
        fail(f"{BENCH_JSON} missing (run benchmarks/bridge_latency.py)")
    bench = json.loads(BENCH_JSON.read_text())
    missing = TOP_KEYS - bench.keys()
    if missing:
        fail(f"missing top-level keys {sorted(missing)}")
    if not VARIANTS <= bench["variants"].keys():
        fail(f"missing variants {sorted(VARIANTS - bench['variants'].keys())}")
    for name, v in bench["variants"].items():
        gone = VARIANT_KEYS - v.keys()
        if gone:
            fail(f"variant {name!r} missing keys {sorted(gone)}")
        bad = [k for k in VARIANT_KEYS if not isinstance(v[k], (int, float))]
        if bad:
            fail(f"variant {name!r} non-numeric keys {bad}")
    m = bench["measured"]
    gone = MEASURED_KEYS - m.keys()
    if gone:
        fail(f"measured section missing keys {sorted(gone)}")
    if len(m["distance_pages_per_round"]) != bench["num_nodes"] - 1:
        fail("distance histogram length != N-1")
    # The acceptance bar: measured steering strictly beats static routing.
    if not m["load_balanced_us"] < m["static_bidirectional_us"]:
        fail(f"load-balanced ({m['load_balanced_us']}us) not below static "
             f"bidirectional ({m['static_bidirectional_us']}us) under the "
             f"measured skew")
    hier = bench["hierarchical"]
    if not HIER_FABRICS <= hier.keys():
        fail(f"missing hierarchical fabrics "
             f"{sorted(HIER_FABRICS - hier.keys())}")
    for label, h in hier.items():
        gone = HIER_KEYS - h.keys()
        if gone:
            fail(f"hierarchical fabric {label!r} missing keys {sorted(gone)}")
        if h["num_boards"] * h["board_size"] != int(label):
            fail(f"hierarchical fabric {label!r}: "
                 f"{h['num_boards']}x{h['board_size']} endpoints mislabeled")
        # The acceptance bar: the two-tier schedule strictly beats the
        # topology-blind flat one under intra-board-heavy traffic.
        if not h["hierarchical_us"] < h["flat_bidirectional_us"]:
            fail(f"fabric {label}: hierarchical ({h['hierarchical_us']}us) "
                 f"not below flat bidirectional "
                 f"({h['flat_bidirectional_us']}us)")
    pipe = bench["pipeline"]
    gone = PIPELINE_KEYS - pipe.keys()
    if gone:
        fail(f"pipeline section missing keys {sorted(gone)}")
    sweep = pipe["model_round_us"]
    gone = PIPELINE_CHANNELS - sweep.keys()
    if gone:
        fail(f"pipeline sweep missing depths {sorted(gone)}")
    bad = [c for c in PIPELINE_CHANNELS
           if not isinstance(sweep[c], (int, float))]
    if bad:
        fail(f"pipeline sweep non-numeric depths {sorted(bad)}")
    gone = PIPELINE_PICKS - pipe["selected_channels"].keys()
    if gone:
        fail(f"pipeline selected_channels missing regimes {sorted(gone)}")
    # The acceptance bar: at 8 devices the pipelined engine's modeled round
    # latency never exceeds the serial engine's, monotonically in depth.
    prev = sweep["1"]
    for c in ("2", "4", "8"):
        if sweep[c] > prev:
            fail(f"pipeline depth {c} ({sweep[c]}us) above depth "
                 f"{'1248'['1248'.index(c) - 1]} ({prev}us)")
        prev = sweep[c]
    if not sweep["4"] <= sweep["1"]:
        fail(f"pipelined ({sweep['4']}us) above serial ({sweep['1']}us)")
    # Wall-clock sweep (present when the bench ran on a real 8-device
    # ring): with the fused datapath this is an acceptance bar, not just a
    # schema check.  The fused engine issues one collective pair per round
    # regardless of depth, so the measured epoch time must stay inside a
    # tolerance band of the serial engine's at every channels > 1 — a
    # dispatch-overhead regression (the unfused engines' 37ms -> 121ms
    # monotonic blow-up from channels 1 -> 8) fails CI here.
    if "measured_us_per_call" in pipe:
        mus = pipe["measured_us_per_call"]
        gone = PIPELINE_CHANNELS - mus.keys()
        if gone:
            fail(f"pipeline measured sweep missing depths {sorted(gone)}")
        bad = [c for c in PIPELINE_CHANNELS
               if not isinstance(mus[c], (int, float))]
        if bad:
            fail(f"pipeline measured sweep non-numeric depths {sorted(bad)}")
        band = MEASURED_SWEEP_BAND * mus["1"]
        over = {c: mus[c] for c in PIPELINE_CHANNELS if mus[c] > band}
        if over:
            fail(f"measured pipeline sweep regresses with depth: {over} "
                 f"above {MEASURED_SWEEP_BAND}x the serial engine's "
                 f"{mus['1']}us — per-round dispatch is scaling with "
                 f"channels again")
        if "model_vs_measured_error" not in pipe:
            fail("pipeline measured sweep missing model_vs_measured_error")
        err = pipe["model_vs_measured_error"]
        bad = [k for k in set(PIPELINE_CHANNELS) | {"mean"}
               if not isinstance(err.get(k), (int, float))]
        if bad:
            fail(f"model_vs_measured_error non-numeric keys {sorted(bad)}")
        if "phase_breakdown" not in pipe:
            fail("pipeline measured sweep missing phase_breakdown")
        check_phase_breakdown(pipe["phase_breakdown"], bench["num_nodes"])
    # Fused-vs-unfused epoch comparison: when measured on a real ring, the
    # fused Pallas datapath must beat the unfused chain at both the
    # wire-bound and the latency-bound page size.
    fus = bench["fused"]
    if "page_sweep" not in fus or "source" not in fus:
        fail("fused section missing page_sweep/source")
    if fus["page_sweep"]:
        gone = FUSED_PAGE_SIZES - fus["page_sweep"].keys()
        if gone:
            fail(f"fused page sweep missing sizes {sorted(gone)}")
        for label, e in fus["page_sweep"].items():
            bad = [k for k in ("fused_us", "unfused_us", "speedup")
                   if not isinstance(e.get(k), (int, float))]
            if bad:
                fail(f"fused {label!r} non-numeric keys {bad}")
            if not e["fused_us"] < e["unfused_us"]:
                fail(f"fused epoch at {label} ({e['fused_us']}us) not "
                     f"below unfused ({e['unfused_us']}us)")
    elif "ring" in fus["source"]:
        fail("fused section measured on a ring but has no page sweep")
    ten = bench["tenancy"]
    gone = TENANCY_KEYS - ten.keys()
    if gone:
        fail(f"tenancy section missing keys {sorted(gone)}")
    for key in ("windows", "refit_windows", "tenant_served",
                "tenant_spilled"):
        if not TENANCY_TENANTS <= ten[key].keys():
            fail(f"tenancy {key} missing tenants "
                 f"{sorted(TENANCY_TENANTS - ten[key].keys())}")
    bad = [k for k in ("interactive_solo_us", "interactive_naive_us",
                       "interactive_qos_us", "qos_isolation_ratio",
                       "naive_degradation_ratio")
           if not isinstance(ten[k], (int, float))]
    if bad:
        fail(f"tenancy non-numeric keys {bad}")
    # The isolation acceptance bar: QoS scheduling bounds the interactive
    # tenant's co-located latency; naive equal-FIFO sharing does not.
    if not ten["qos_isolation_ratio"] <= TENANCY_ISOLATION_BOUND:
        fail(f"tenancy: QoS isolation ratio {ten['qos_isolation_ratio']} "
             f"above the {TENANCY_ISOLATION_BOUND}x acceptance bound")
    if not ten["naive_degradation_ratio"] > ten["qos_isolation_ratio"]:
        fail(f"tenancy: naive sharing ({ten['naive_degradation_ratio']}x) "
             f"not worse than QoS ({ten['qos_isolation_ratio']}x) — the "
             f"scheduler is not isolating anything")
    if ten["tenant_served"]["interactive"] <= 0:
        fail("tenancy: interactive tenant served no pages")
    cal_str = check_calibration(bench["calibration"])
    alert_str = check_alerts(bench["alerts"])
    trace_str = check_trace()
    serve_str = check_serve()
    h8 = hier["8"]
    if fus["page_sweep"]:
        fstr = ", fused " + " ".join(
            f"{lbl} x{e['speedup']}" for lbl, e in fus["page_sweep"].items())
    else:
        fstr = ""
    print(f"BENCH_bridge.json ok:{fstr}\n  "
          f"{len(bench['variants'])} variants, "
          f"measured {m['source']}: static {m['static_bidirectional_us']}us "
          f"-> load-balanced {m['load_balanced_us']}us; hierarchical 2x4 "
          f"{h8['flat_bidirectional_us']}us -> {h8['hierarchical_us']}us; "
          f"pipeline c1 {sweep['1']}us -> c8 {sweep['8']}us "
          f"(picks: {pipe['selected_channels']}); tenancy "
          f"{ten['source']}: solo {ten['interactive_solo_us']}us -> qos "
          f"{ten['interactive_qos_us']}us (x{ten['qos_isolation_ratio']}) "
          f"vs naive x{ten['naive_degradation_ratio']}; {cal_str}; "
          f"{alert_str}; {trace_str}; {serve_str}")


if __name__ == "__main__":
    main()
