"""Request-level serving benchmark -> BENCH_serve.json.

Three closed-loop runs of the continuous batcher
(:mod:`repro.serve.batcher`) over seeded synthetic traffic
(:mod:`repro.serve.traffic`), plus a real-model fidelity check:

* **fidelity** — a reduced granite decode serves a mixed request stream
  through the continuous batcher; every retired sequence's tokens are
  compared bit-for-bit against :func:`~repro.serve.batcher.solo_reference`
  running the same request alone in a fixed batch.  Continuous batching
  must be a pure scheduling change — zero numerical drift.
* **scale** — the QoS batcher under a batch-tenant *flood*: a steady
  interactive stream plus a burst of ~1.5k batch requests into a 64-slot
  decode batch, driving peak in-flight concurrency past 1,000 sequences
  while KV pages lease and retire through the orchestrated pool.
  Reports per-QoS-class p50/p99 round latencies and goodput.
* **isolation** — the same interactive stream (identical per-tenant rng
  streams, so byte-identical arrivals) measured three ways: solo,
  co-located with the flood under QoS slot admission, and co-located
  under naive global-FIFO admission.  The acceptance bars (enforced by
  ``validate_bench.py``): QoS keeps the interactive p99 within
  ``SERVE_ISOLATION_BOUND``x of solo; naive FIFO is strictly worse.

Run:  PYTHONPATH=src:. python benchmarks/serve_bench.py [--quick]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import time

import numpy as np

from repro.core.control_plane import ControlPlane
from repro.obs.metrics import MetricsRegistry
from repro.orchestrator.orchestrator import Orchestrator
from repro.orchestrator.tenants import TenantSpec
from repro.serve.batcher import (ContinuousBatcher, ModelDecodeEngine,
                                 SimulatedDecodeEngine, serve_loop,
                                 solo_reference)
from repro.serve.traffic import TenantTraffic, TrafficGenerator, make_request

OUT = pathlib.Path(__file__).resolve().parent.parent / "BENCH_serve.json"

SEED = 0
STEP_US = 100.0            # modeled decode-step latency for the sim clock
PAGE_TOKENS = 16

INTERACTIVE, BATCH = 1, 2


def _mk_orchestrator(num_slots: int) -> Orchestrator:
    # Pool with headroom: slot admission, not raw page capacity, governs.
    pages_per_seq = (128 + 64) // PAGE_TOKENS          # worst-case request
    cp = ControlPlane(8, num_slots * pages_per_seq,
                      num_logical=8 * num_slots * pages_per_seq, seed=SEED)
    orc = Orchestrator(cp, budget=8, control_period=4, migrate=False)
    orc.register(TenantSpec(INTERACTIVE, "chat", qos="interactive",
                            share=4.0))
    orc.register(TenantSpec(BATCH, "crawl", qos="batch", share=1.0))
    return orc


def _interactive_traffic(steps: int) -> TenantTraffic:
    return TenantTraffic(INTERACTIVE, rate=1.5, prompt_mean=12,
                         output_mean=8, prompt_max=64, output_max=48,
                         stop_step=steps)


def _flood_traffic(rate: float, start: int, stop: int) -> TenantTraffic:
    return TenantTraffic(BATCH, rate=rate, prompt_mean=24, output_mean=12,
                         prompt_max=128, output_max=64,
                         start_step=start, stop_step=stop)


def _sim_run(policy: str, num_slots: int, steps: int,
             mix) -> tuple[dict, ContinuousBatcher]:
    orc = _mk_orchestrator(num_slots)
    registry = MetricsRegistry()
    batcher = ContinuousBatcher(orc, num_slots=num_slots,
                                page_tokens=PAGE_TOKENS, policy=policy,
                                registry=registry)
    engine = SimulatedDecodeEngine(num_slots)
    traffic = TrafficGenerator(mix, seed=SEED)
    result = serve_loop(batcher, engine, traffic, steps=steps,
                        step_us=STEP_US)
    return result, batcher


def _qclean(fam: dict) -> dict:
    return {qos: {k: (int(v) if k == "count" else round(float(v), 3))
                  for k, v in q.items()} for qos, q in fam.items()}


def run_fidelity(quick: bool) -> dict:
    """Real-model continuous batching vs solo decode, bit-for-bit."""
    import jax
    import jax.numpy as jnp

    from repro import configs
    from repro.config import RunConfig, ShapeConfig
    from repro.models import transformer

    batch, max_len, pt = 4, 32, 8
    cfg = dataclasses.replace(configs.get_reduced("granite-3-8b"),
                              dtype="float32")
    shape = ShapeConfig("serve_bench", max_len, batch, "decode")
    params = transformer.init_params(cfg, jax.random.key(0))
    placements = ["local"] if quick else ["local", "bridge_pull"]
    n_reqs = 6 if quick else 8
    reqs = [make_request(i, INTERACTIVE + (i % 2), prompt_len=3 + i % 5,
                         output_len=4 + i % 6, seed=3, vocab=cfg.vocab_size)
            for i in range(n_reqs)]
    out: dict = {"requests": n_reqs, "placements": {},
                 "bit_identical": True}
    for kv in placements:
        run = RunConfig(model=cfg, shape=shape, kv_placement=kv)
        orc = _mk_orchestrator(batch)
        bat = ContinuousBatcher(orc, num_slots=batch, page_tokens=pt)
        eng = ModelDecodeEngine(run, params, batch=batch, max_len=max_len,
                                page_tokens=pt, dtype=jnp.float32)
        for r in reqs:
            bat.submit(r)
        guard = 0
        while bat.in_flight() and guard < 500:
            bat.control()
            if bat.active_count():
                tokens, resets = bat.step_inputs()
                bat.observe(eng.step(tokens, resets))
            guard += 1
        matched = 0
        for seq in bat.retired:
            ref_eng = ModelDecodeEngine(run, params, batch=batch,
                                        max_len=max_len, page_tokens=pt,
                                        dtype=jnp.float32)
            ref = solo_reference(ref_eng, seq.req, slot=seq.slot)
            if ref == seq.out:
                matched += 1
        ok = matched == len(bat.retired) == n_reqs
        out["placements"][kv] = {"completed": len(bat.retired),
                                 "matched": matched, "bit_identical": ok}
        out["bit_identical"] = out["bit_identical"] and ok
        print(f"  fidelity {kv}: {matched}/{len(bat.retired)} sequences "
              f"bit-identical to solo")
    return out


def run_scale(num_slots: int, steps: int, flood_rate: float,
              flood: tuple) -> tuple[dict, dict]:
    """The flood run: scale numbers + the QoS half of the isolation story."""
    mix = [_interactive_traffic(steps),
           _flood_traffic(flood_rate, *flood)]
    result, batcher = _sim_run("qos", num_slots, steps, mix)
    acc = batcher.accounting()
    scale = {
        "num_slots": num_slots,
        "arrival_steps": steps,
        "decode_steps": result["steps"],
        "submitted": result["submitted"],
        "completed": result["completed"],
        "shed": result["shed"],
        "peak_in_flight": result["peak_in_flight"],
        "tokens": result["tokens"],
        "goodput_tokens_per_s": round(result["goodput_tokens_per_s"], 1),
        "latency_us": _qclean(result["latency_us"]),
        "ttft_us": _qclean(result["ttft_us"]),
        "per_tenant": {
            "submitted": {str(t): v for t, v in acc["submitted"].items()},
            "completed": {str(t): v for t, v in acc["completed"].items()},
        },
    }
    print(f"  scale[qos]: peak in-flight {scale['peak_in_flight']}, "
          f"{scale['completed']}/{scale['submitted']} completed, "
          f"goodput {scale['goodput_tokens_per_s']:.0f} tokens/s")
    return scale, result["latency_us"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke scale (same gates, smaller fidelity "
                         "sweep and flood)")
    args = ap.parse_args()
    t0 = time.monotonic()

    num_slots = 32 if args.quick else 64
    steps = 50 if args.quick else 60
    flood = (5, 30)
    flood_rate = 60.0 if args.quick else 70.0

    print("fidelity: real-model continuous batching vs solo")
    fidelity = run_fidelity(args.quick)

    print("scale: QoS batcher under batch flood")
    scale, qos_lat = run_scale(num_slots, steps, flood_rate, flood)

    print("isolation: solo vs qos vs naive")
    solo_res, _ = _sim_run("qos", num_slots, steps,
                           [_interactive_traffic(steps)])
    naive_res, _ = _sim_run("naive", num_slots, steps,
                            [_interactive_traffic(steps),
                             _flood_traffic(flood_rate, *flood)])
    solo_p99 = solo_res["latency_us"]["interactive"]["p99"]
    qos_p99 = qos_lat["interactive"]["p99"]
    naive_p99 = naive_res["latency_us"]["interactive"]["p99"]
    isolation = {
        "interactive_requests": solo_res["latency_us"]["interactive"][
            "count"],
        "interactive_solo_p99_us": round(float(solo_p99), 3),
        "interactive_qos_p99_us": round(float(qos_p99), 3),
        "interactive_naive_p99_us": round(float(naive_p99), 3),
        "qos_isolation_ratio": round(float(qos_p99 / solo_p99), 3),
        "naive_degradation_ratio": round(float(naive_p99 / solo_p99), 3),
    }
    print(f"  interactive p99: solo {solo_p99:.0f}us, qos {qos_p99:.0f}us "
          f"(x{isolation['qos_isolation_ratio']}), naive {naive_p99:.0f}us "
          f"(x{isolation['naive_degradation_ratio']})")

    bench = {
        "source": ("serve_bench --quick" if args.quick else "serve_bench"),
        "config": {"seed": SEED, "step_us": STEP_US,
                   "page_tokens": PAGE_TOKENS, "num_slots": num_slots,
                   "flood_rate": flood_rate, "flood_window": list(flood)},
        "fidelity": fidelity,
        "scale": scale,
        "isolation": isolation,
    }
    OUT.write_text(json.dumps(bench, indent=1, sort_keys=True) + "\n")
    print(f"wrote {OUT} ({time.monotonic() - t0:.1f}s)")


if __name__ == "__main__":
    main()
