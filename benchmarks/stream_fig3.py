"""Paper Figure 3 reproduction: STREAM, local vs software-defined remote.

Three layers of evidence:
  1. the analytical datapath model (core/perfmodel.py) reproduces the
     published numbers — 1280 MiB/s transceiver ceiling, 562 MiB/s 1-core
     remote copy (−47 %), saturation beyond 2 masters, −25 % penalty for the
     FLOP-carrying kernels;
  2. the Pallas STREAM kernels run (interpret mode on CPU) against local
     arrays AND against bridge-delivered pages, byte-identically — the TPU
     equivalent of the paper's local/remote NUMA-domain switch;
  3. the TPU projection: the same pipeline model with v5e constants says
     what disaggregated STREAM costs on a pod.

Emits CSV rows: name,us_per_call,derived.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bridge, perfmodel
from repro.core.memport import MemPortTable
from repro.kernels import ops, ref


def model_rows() -> list[str]:
    rows = []
    table = perfmodel.stream_table()
    for kernel, sides in table.items():
        for cores in range(1, 5):
            loc = sides["local"][cores - 1]
            rem = sides["remote"][cores - 1]
            pen = 1.0 - rem / loc
            rows.append(
                f"fig3_model_{kernel}_{cores}core,0,"
                f"local={loc:.0f}MiB/s remote={rem:.0f}MiB/s "
                f"penalty={pen:.1%}")
    # paper anchors
    rows.append(f"fig3_anchor_link_ceiling,0,"
                f"{perfmodel.PAPER_HW.link_payload_mibps:.0f}MiB/s (paper 1280)")
    rows.append(f"fig3_anchor_rtt,0,"
                f"{perfmodel.PAPER_HW.rtt_ns:.0f}ns (paper 800)")
    rows.append(f"fig3_anchor_copy1_remote,0,"
                f"{perfmodel.stream_bandwidth_mibps('copy', 1, True):.0f}"
                f"MiB/s (paper 562)")
    rows.append(f"fig3_anchor_copy1_penalty,0,"
                f"{perfmodel.penalty('copy', 1):.1%} (paper 47%)")
    rows.append(f"fig3_anchor_scale1_penalty,0,"
                f"{perfmodel.penalty('scale', 1):.1%} (paper ~25%)")
    for k in ("copy", "scale", "add", "triad"):
        rows.append(f"fig3_tpu_projection_{k},0,"
                    f"penalty={perfmodel.tpu_stream_penalty(k):.1%}")
    return rows


def kernel_rows(n: int = 128 * 512) -> list[str]:
    """STREAM kernels against local arrays vs bridge-delivered pages."""
    rng = np.random.default_rng(0)
    rows = []
    c = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))

    # local
    t0 = time.perf_counter()
    local = ops.stream_triad(b, c, 3.0)
    jax.block_until_ready(local)
    t_local = (time.perf_counter() - t0) * 1e6

    # remote: array lives as pool pages on 4 logical nodes; pull through the
    # bridge, then run the same kernel on the delivered pages
    page = 2048
    num_pages = n // page
    # blocked layout: pool row == logical page id (content laid out in place)
    table = MemPortTable.blocked(num_pages, 4, -(-num_pages // 4))
    pool_c = c.reshape(num_pages, page)
    pool_b = b.reshape(num_pages, page)
    want = jnp.arange(num_pages, dtype=jnp.int32)[None, :]
    t0 = time.perf_counter()
    c_rem = bridge.pull_pages(pool_c, want, table, mesh=None, budget=8,
                              table_nodes=4)[0].reshape(-1)
    b_rem = bridge.pull_pages(pool_b, want, table, mesh=None, budget=8,
                              table_nodes=4)[0].reshape(-1)
    remote = ops.stream_triad(b_rem, c_rem, 3.0)
    jax.block_until_ready(remote)
    t_remote = (time.perf_counter() - t0) * 1e6

    np.testing.assert_allclose(np.asarray(local), np.asarray(remote),
                               atol=1e-6)
    rows.append(f"stream_triad_local,{t_local:.0f},bytes={n*12}")
    rows.append(f"stream_triad_via_bridge,{t_remote:.0f},"
                f"identical_result=True")
    return rows


def run() -> list[str]:
    return model_rows() + kernel_rows()


if __name__ == "__main__":
    for r in run():
        print(r)
