import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Perf-iteration microscope: recompile one dry-run cell and print the top
HBM-traffic and collective contributors with their computation multipliers.

  PYTHONPATH=src python -m benchmarks.inspect_cell --arch starcoder2-7b \
      --shape prefill_32k [--kv bridge_pull] [--multi-pod]
"""
import argparse  # noqa: E402
import sys  # noqa: E402
import pathlib  # noqa: E402

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO))
sys.path.insert(0, str(REPO / "src"))

from benchmarks import hlo_analysis as H  # noqa: E402
from repro.launch import dryrun  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--kv", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--budget", type=int, default=8)
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--dump", default="")
    args = ap.parse_args()

    lowered, meta = dryrun.build_cell(args.arch, args.shape,
                                      multi_pod=args.multi_pod,
                                      kv_placement=args.kv,
                                      bridge_budget=args.budget)
    compiled = lowered.compile()
    text = compiled.as_text()
    if args.dump:
        pathlib.Path(args.dump).write_text(text)
    comps = H.parse_hlo(text)
    stats = H.analyze(text)
    # mark fused computations so the listing matches analyze()'s accounting
    for comp in comps.values():
        for ins in comp.instructions:
            if ins.opcode == "fusion":
                import re as _re
                for cm in _re.finditer(r"calls=%?([\w\.\-]+)", ins.raw):
                    if cm.group(1) in comps:
                        comps[cm.group(1)].is_fused = True

    # recompute per-instruction charges with multipliers
    mult = {}
    entry = comps.get("ENTRY") or next(iter(comps.values()))
    mult[entry.name] = 1.0
    import re
    changed, iters = True, 0
    while changed and iters < 100:
        changed, iters = False, iters + 1
        for cname, comp in comps.items():
            base = mult.get(cname, 0.0)
            if base == 0.0:
                continue
            for ins in comp.instructions:
                trips = 1.0
                if ins.opcode == "while":
                    tm = H._TRIP.search(ins.raw)
                    trips = float(tm.group(1)) if tm else 1.0
                for cm in H._CALL_ATTR.finditer(ins.raw):
                    single, multi = cm.groups()
                    names = ([single] if single else
                             [s.strip().lstrip("%")
                              for s in (multi or "").split(",")])
                    for cn in names:
                        if cn in comps:
                            f = trips if ins.opcode == "while" else 1.0
                            if mult.get(cn, 0.0) < base * f:
                                mult[cn] = base * f
                                changed = True

    items = []
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0 or comp.is_fused:
            continue
        for ins in comp.instructions:
            if ins.opcode in H.SKIP_HBM_OPS:
                continue
            b = m * H._instr_hbm_bytes(comps, comp, ins)
            if b > 0:
                items.append((b, m, cname[:34], ins.opcode,
                              ins.result_shape[:70],
                              ins.raw.strip()[:60]))
    items.sort(reverse=True)
    print(f"=== {meta} ===")
    print(f"flops={stats.flops:.3e} hbm={stats.hbm_bytes:.3e} "
          f"coll={stats.collective_bytes:.3e}")
    print(f"\n--- top {args.top} HBM contributors ---")
    for b, m, cn, op, shape, raw in items[: args.top]:
        print(f"{b:12.3e}  x{m:<5.0f} {cn:<34s} {op:<18s} {shape}")
    print(f"\n--- top collectives ---")
    for t in stats.top_collectives[: args.top]:
        print(f"{t['bytes']:12.3e}  x{t['mult']:<5.0f} {t['op']:<20s} "
              f"{t['shape']}")


if __name__ == "__main__":
    main()
