"""Paper §3 latency reproduction: the 134-cycle / 800 ns round trip.

Reports the stage-by-stage pipeline budget (design partition), checks it
sums to the published total, and measures the *software* path length of our
bridge datapath (translation -> steering -> epochs) in ops/epochs per pull,
which is the TPU-side analogue of the cycle count.

Emits CSV rows: name,us_per_call,derived.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bridge, perfmodel
from repro.core.memport import MemPortTable


def rows() -> list[str]:
    out = []
    total = sum(perfmodel.RTT_PIPELINE_CYCLES.values())
    for stage, cyc in perfmodel.RTT_PIPELINE_CYCLES.items():
        ns = cyc / perfmodel.PAPER_HW.clock_mhz * 1e3
        out.append(f"rtt_stage_{stage.split('(')[0].strip().replace(' ', '_')},"
                   f"0,{cyc}cyc={ns:.0f}ns")
    out.append(f"rtt_total,0,{total}cyc={total/perfmodel.PAPER_HW.clock_mhz*1e3:.0f}ns"
               f" (paper: 134cyc=800ns)")

    # software path: one-page pull latency through the loopback bridge
    table = MemPortTable.striped(16, 4, 4)
    pool = jnp.asarray(np.random.default_rng(0).normal(
        size=(16, 256)).astype(np.float32))
    want = jnp.asarray([[3]], jnp.int32)
    pull = jax.jit(lambda p, w, t: bridge.pull_pages(
        p, w, t, mesh=None, budget=1, table_nodes=4))
    jax.block_until_ready(pull(pool, want, table))  # compile
    t0 = time.perf_counter()
    reps = 50
    for _ in range(reps):
        r = pull(pool, want, table)
    jax.block_until_ready(r)
    us = (time.perf_counter() - t0) / reps * 1e6
    out.append(f"bridge_sw_pull_1page,{us:.1f},loopback_jitted")

    # modelled TPU pull-mode page latency (1 hop, 256 KiB page)
    lat_us = (2 * perfmodel.TPU_HW.ici_hop_latency_us
              + (1 << 18) / (perfmodel.TPU_HW.ici_link_gbps * 1e9) * 1e6)
    out.append(f"bridge_tpu_page_rtt_model,0,{lat_us:.1f}us_per_256KiB_page")
    bw = perfmodel.tpu_remote_page_bandwidth_gbps(1 << 18)
    out.append(f"bridge_tpu_pull_bandwidth_model,0,{bw:.1f}GB/s_per_pair")
    return out


def run() -> list[str]:
    return rows()


if __name__ == "__main__":
    for r in run():
        print(r)
