"""Paper §3 latency reproduction: the 134-cycle / 800 ns round trip.

Reports the stage-by-stage pipeline budget (design partition), checks it
sums to the published total, and measures the *software* path length of our
bridge datapath (translation -> steering -> epochs) in ops/epochs per pull,
which is the TPU-side analogue of the cycle count.

Also compares route-program schedule variants (unidirectional /
bidirectional / pruned / load_balanced): circuit epochs, wired slots, bytes
per round and the analytical round latency from ``repro.core.perfmodel``.
The ``load_balanced`` variant closes the software-defined loop: a skewed
traffic scenario runs through the bridge with ``collect_telemetry=True``
(on a real 8-way mem ring when 8 devices exist, through the telemetry
oracle otherwise), the measured distance loads compile a load-balanced
program, and its predicted round latency under the *measured* loads is
recorded against the static bidirectional split's.

The ``pipeline`` section sweeps the pipelined multi-channel round engine
(``channels``): modeled round latency per depth, real-datapath wall-clock
per depth on an 8-device ring when one exists (fused and unfused engines
both, plus a normalized ``model_vs_measured_error`` record), and the
control plane's telemetry-driven depth pick at a wire-bound and a
latency-bound page size.

The ``fused`` section times the fused Pallas datapath against the unfused
ppermute-chain escape hatch at the wire-bound (256 KiB) and latency-bound
(4 KiB) page sizes and counts copies/collectives in both lowered HLO
programs; it is also written standalone to ``BENCH_fused_compare.json``
(the CI comparison artifact).

The ``tenancy`` section co-locates an interactive decode tenant with a
batch-pull noisy neighbour through ``repro.orchestrator``: the same offered
load is priced solo, under naive FIFO sharing, and under the orchestrator's
weighted-fair QoS windows — the acceptance bar keeps the interactive
tenant's completion latency within 1.5x of its solo run while naive
sharing degrades with the backlog depth.

The ``calibration`` section closes the observability loop (``repro.obs``):
every measured scenario runs inside a fenced trace span (the whole run's
span tree is written to ``BENCH_trace.json``, openable in Perfetto), each
timed pull contributes a ``(route-feature, measured us)`` sample, and a
``repro.core.perfmodel.Calibrator`` RLS fit of the analytic model's
constants is compared against the static datasheet prior per scenario —
``validate_bench.py`` gates fitted <= static.  ``pipeline`` additionally
records a per-depth ``phase_breakdown`` from ``obs:<phase>`` named-scope
op counts in the compiled HLO, attributing the unfused depth>1 wall-clock
regression to steering-collective dispatch.

Emits CSV rows: name,us_per_call,derived — and writes the same data
machine-readably to ``BENCH_bridge.json`` at the repo root so the perf
trajectory is tracked across PRs (schema checked by
``benchmarks/validate_bench.py`` in CI; ``--quick`` trims timing reps for
the smoke job).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
from benchmarks import hlo_analysis  # noqa: E402

from repro.core import bridge, perfmodel, ref, steering
from repro.core.control_plane import ControlPlane
from repro.core.memport import MemPortTable
from repro.core.topology import Topology
from repro.obs import TraceRecorder, phase_op_counts
from repro.orchestrator import Orchestrator, TenantSpec
from repro.telemetry import TelemetryAggregator

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_bridge.json"
# Standalone fused-vs-unfused comparison record (CI uploads it next to
# BENCH_bridge.json so the fused-datapath claim is a first-class artifact).
FUSED_JSON = BENCH_JSON.with_name("BENCH_fused_compare.json")
# Chrome-trace/Perfetto span record of every measured scenario in this run
# (CI uploads it; open at https://ui.perfetto.dev).
TRACE_JSON = BENCH_JSON.with_name("BENCH_trace.json")
# Postmortem archive of the sentinel drill's orchestrator (flight journal +
# metrics + state; ``repro.obs.replay()`` re-executes the journal).
BUNDLE_ZIP = BENCH_JSON.with_name("BENCH_debug_bundle.zip")

# Online-calibration fit: RLS passes over the measured-scenario samples
# (deterministic order, so the fitted constants are reproducible given the
# same wall-clock samples).
CAL_EPOCHS = 4

# Route-program comparison geometry: an 8-node mem ring moving 256 KiB pages
# in rounds of 8; "pruned" keeps the three distances a blocked/affinity
# placement typically exercises.
ROUTE_NODES = 8
ROUTE_PAGE_BYTES = 1 << 18
ROUTE_BUDGET = 8

# Skewed-traffic scenario: every requester hammers its three nearest
# clockwise neighbours 6:3:2 (hotspot locality) — the shape that makes the
# static min(d, N-d) split pile every live circuit onto one direction.
SKEW_PAGES = {1: 6, 2: 3, 3: 2}

# Hierarchical fabrics compared flat-vs-two-tier: the real 8-endpoint ring
# (2 boards x 4) plus simulated rack-scale 16 and 32 endpoint fabrics.
HIER_FABRICS = {"8": (2, 4), "16": (4, 4), "32": (4, 8)}

# Pipelined round-engine depth sweep (the channels knob): modeled round
# latency per depth, wall-clock on the real 8-ring when available, and the
# control plane's telemetry-driven pick at a wire-bound (256 KiB) and a
# latency-bound (4 KiB) page size.
PIPELINE_CHANNELS = (1, 2, 4, 8)
SMALL_PAGE_BYTES = 4096

# Fused-vs-unfused epoch comparison geometry: the wire-bound (256 KiB) and
# latency-bound (4 KiB) page sizes of the control plane's two regimes.
FUSED_PAGE_SIZES = {"256KiB": 1 << 18, "4KiB": SMALL_PAGE_BYTES}
# Intra-board-heavy traffic: pages pulled from each board mate at local
# ring delta 1/2/3+ (hotspot locality *within* the board).
INTRA_PAGES = {1: 6, 2: 3, 3: 2}

# Multi-tenant co-location scenario: a latency-sensitive interactive decode
# tenant (6 near-neighbour pages per node per step, 3:1 budget share) next
# to a batch-pull noisy neighbour with a deep striped backlog.
TENANCY_INTERACTIVE_PAGES = {1: 3, 2: 3}   # per node, by ring distance
TENANCY_BATCH_BACKLOG = 40                 # pages per node, striped homes


def route_variants() -> dict[str, steering.RouteProgram]:
    bi = steering.bidirectional_program(ROUTE_NODES)
    return {
        "unidirectional": steering.unidirectional_program(ROUTE_NODES),
        "bidirectional": bi,
        "pruned": steering.pruned_program(bi, [1, 2, 6]),
    }


def measure_sw_pull_us(reps: int = 50) -> float:
    """One-page pull latency through the loopback bridge (jitted)."""
    table = MemPortTable.striped(16, 4, 4)
    pool = jnp.asarray(np.random.default_rng(0).normal(
        size=(16, 256)).astype(np.float32))
    want = jnp.asarray([[3]], jnp.int32)
    pull = jax.jit(lambda p, w, t: bridge.pull_pages(
        p, w, t, mesh=None, budget=1, table_nodes=4))
    jax.block_until_ready(pull(pool, want, table))  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        r = pull(pool, want, table)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps * 1e6


def skewed_traffic_scenario(recorder: TraceRecorder | None = None,
                            samples: list | None = None,
                            quick: bool = False) -> tuple:
    """Measure a skewed matrix, recompile, compare predicted latencies.

    Returns ``(measured, program, aggregator, control_plane)``: the
    ``measured`` section of BENCH_bridge.json — per-distance measured pages
    per round, the static-bidirectional vs load-balanced predicted round
    latency under those loads, and how the telemetry was captured (real
    8-device ring or oracle counters) — plus the telemetry-compiled
    load-balanced program and the aggregator / control plane that compiled
    it (``pipeline_sweep`` reuses them for the measured channels pick).

    When running on the real ring the pull is also wall-clock timed inside
    a fenced trace span (annotated with the exact bridge counters) and a
    ``(features, measured_us)`` calibration sample is appended to
    ``samples`` — the feature vector prices the *actual* moved bytes, not
    the scenario's nominal 256 KiB page, so the fit sees what ran.
    """
    n, ppn = ROUTE_NODES, 16
    cp = ControlPlane(num_nodes=n, pages_per_node=ppn, num_logical=n * ppn)
    cp.allocate(n * ppn, policy="striped")   # page p -> home p % n
    table = cp.table()
    # Node i requests SKEW_PAGES[d] pages homed at (i + d) % n.
    want_rows = []
    for i in range(n):
        row = []
        for d, count in SKEW_PAGES.items():
            h = (i + d) % n
            row += [h + n * k for k in range(count)]   # striped: home = id % n
        want_rows.append(row)
    want = np.asarray(want_rows, np.int32)
    rounds = steering.num_rounds(want.shape[1], ROUTE_BUDGET)

    source = "oracle"
    measured_pull_us = None
    if jax.device_count() >= n:
        source = f"{n}-device ring"
        mesh = jax.make_mesh((n,), ("data",))
        pool = jnp.zeros((n * ppn, 4), jnp.float32)
        rec = recorder if recorder is not None else TraceRecorder()
        reps = 2 if quick else 5
        with bridge.use_mesh(mesh):
            pull = jax.jit(lambda p, w, t: bridge.pull_pages(
                p, w, t, mesh=mesh, budget=ROUTE_BUDGET,
                collect_telemetry=True))
            wj = jnp.asarray(want)
            jax.block_until_ready(pull(pool, wj, table))   # compile
            t0 = time.perf_counter()
            with rec.span("transfer:skewed", scenario="skewed",
                          rounds=rounds, reps=reps) as sp:
                for _ in range(reps):
                    r = pull(pool, wj, table)
                rec.fence(r)
            measured_pull_us = (time.perf_counter() - t0) / reps * 1e6
        _, telem = r
        rec.annotate_telemetry(sp, telem, page_bytes=pool.shape[1] * 4)
        if samples is not None:
            samples.append({
                "scenario": "skewed", "name": "skewed_pull",
                "features": [round(float(x), 6) for x in
                             perfmodel.route_features(
                                 steering.bidirectional_program(n),
                                 pool.shape[1] * 4, ROUTE_BUDGET,
                                 rounds=rounds)],
                "measured_us": round(measured_pull_us, 1)})
    else:
        telem = ref.expected_transfer_telemetry(
            want, table, steering.bidirectional_program(n), num_nodes=n,
            budget=ROUTE_BUDGET)

    agg = TelemetryAggregator(n, page_bytes=ROUTE_PAGE_BYTES)
    agg.update(telem)
    lb = cp.route_program(telemetry=agg)
    lb.validate()
    # Measured pages per slot per requester-round: what one bridge round
    # actually moves under this matrix.
    slot_pages = agg.distance_pages() / (n * rounds)
    bi = steering.bidirectional_program(n)
    lat_bi = perfmodel.predict_round_latency_us(
        bi, ROUTE_PAGE_BYTES, ROUTE_BUDGET, slot_pages=slot_pages)
    lat_lb = perfmodel.predict_round_latency_us(
        lb, ROUTE_PAGE_BYTES, ROUTE_BUDGET, slot_pages=slot_pages)
    out = {
        "source": source,
        "skew_pages": {str(d): c for d, c in SKEW_PAGES.items()},
        "distance_pages_per_round": [round(float(x), 3) for x in slot_pages],
        "spilled": int(np.asarray(telem.spilled).sum()),
        "pruned": int(np.asarray(telem.pruned).sum()),
        "static_bidirectional_us": round(lat_bi, 2),
        "load_balanced_us": round(lat_lb, 2),
    }
    if measured_pull_us is not None:
        out["measured_pull_us"] = round(measured_pull_us, 1)
    return out, lb, agg, cp


def _phase_breakdown(phase_ops: dict, measured: dict,
                     measured_unfused: dict) -> dict:
    """Attribute the pipeline-depth wall-clock to datapath phases.

    The unfused engine's op count inside the ``obs:`` scopes scales with
    ``2*(N-1)*channels`` (every extra channel re-runs the whole
    request/data ppermute ladder per chunk), while the fused engine keeps
    one request all_gather and a fixed payload exchange at any depth.  A
    linear fit of measured wall-clock against scoped op count across the
    unfused sweep yields the per-op dispatch cost on this backend; each
    phase's attributed share is ``us_per_op * its op count``.  This is the
    measured explanation of the depth>1 slowdown first recorded in the
    pipelined-engine PR: dispatch grows with depth, and on an emulated
    synchronous ring no overlap exists to pay for it.
    """
    depths = sorted(phase_ops["unfused"], key=int)
    totals = {c: sum(phase_ops["unfused"][c].values()) for c in depths}
    xs = np.array([totals[c] for c in depths], float)
    ys = np.array([measured_unfused[c] for c in depths], float)
    if len(depths) > 1 and float(np.ptp(xs)) > 0:
        us_per_op, base_us = (float(v) for v in np.polyfit(xs, ys, 1))
    else:
        us_per_op, base_us = 0.0, float(ys.mean()) if len(depths) else 0.0
    out: dict = {"unfused": {}, "fused": {}}
    for c in depths:
        ops = phase_ops["unfused"][c]
        out["unfused"][c] = {
            "total_us": measured_unfused[c],
            "phase_ops": ops,
            "total_ops": totals[c],
            "attributed_us": {ph: round(us_per_op * k, 1)
                              for ph, k in sorted(ops.items())},
        }
    for c in sorted(phase_ops["fused"], key=int):
        ops = phase_ops["fused"][c]
        out["fused"][c] = {
            "total_us": measured[c],
            "phase_ops": ops,
            "total_ops": sum(ops.values()),
        }
    out["dispatch_us_per_op"] = round(us_per_op, 2)
    out["dispatch_base_us"] = round(base_us, 1)
    out["finding"] = (
        "unfused wall-clock grows with depth because every extra channel "
        "adds another 2*(N-1) steering collectives per round (the "
        "wire_req/wire_data op counts scale with channels) and the "
        "emulated host ring pays per-op dispatch with nothing "
        "overlapping; the fused engine's phase op counts stay flat, so "
        "does its wall-clock. The modeled overlap win needs a real wire; "
        "here the calibrated per-chunk overhead keeps select_channels "
        "serial.")
    return out


def pipeline_sweep(agg: TelemetryAggregator, cp: ControlPlane,
                   quick: bool = False,
                   recorder: TraceRecorder | None = None,
                   samples: list | None = None) -> dict:
    """Pipeline-depth sweep: the pipelined multi-channel round engine.

    Models one bridge round at every depth in PIPELINE_CHANNELS (worst-case
    budget loads on the bidirectional schedule — the overlap term hides
    min(wire, RTT) behind max(wire, RTT) with 1/channels exposed), times the
    real jitted datapath per depth on an 8-device ring when one exists, and
    records the control plane's telemetry pick at a wire-bound and a
    latency-bound page size.  Acceptance (validate_bench.py): every
    channels > 1 modeled round latency <= the serial engine's.  The
    wall-clock numbers are informational only: the host-CPU ring emulates
    ppermute synchronously (nothing can overlap) and pays per-op dispatch
    for the smaller chunked gathers, so the overlap win exists only where
    the wire is real (the model's regime).

    The ``phase_breakdown`` record makes that attribution evidence, not
    narrative: every depth's compiled program is counted per
    ``obs:<phase>`` named scope (``repro.obs.phase_op_counts``), and a
    linear dispatch fit ``measured_us ~ base + us_per_op * phase_ops``
    over the unfused sweep prices each phase's share of the measured
    wall-clock.  Each timed loop also runs inside a fenced trace span and
    appends a calibration sample (features x measured wall-clock) to
    ``samples``.
    """
    bi = steering.bidirectional_program(ROUTE_NODES)
    model = {str(c): round(perfmodel.predict_round_latency_us(
        bi, ROUTE_PAGE_BYTES, ROUTE_BUDGET, channels=c), 2)
        for c in PIPELINE_CHANNELS}
    out: dict = {
        "source": "model",
        "model_round_us": model,
        "selected_channels": {
            "wire_bound_256KiB": cp.select_channels(
                ROUTE_BUDGET, ROUTE_PAGE_BYTES, telemetry=agg),
            "latency_bound_4KiB": cp.select_channels(
                ROUTE_BUDGET, SMALL_PAGE_BYTES, telemetry=agg),
        },
    }
    n, ppn = ROUTE_NODES, 16
    if jax.device_count() >= n:
        out["source"] = f"{n}-device ring"
        mesh = jax.make_mesh((n,), ("data",))
        rng = np.random.default_rng(3)
        pool = jnp.asarray(rng.normal(size=(n * ppn, 64)).astype(np.float32))
        table = MemPortTable.striped(n * ppn, n, ppn)
        want = jnp.asarray(
            rng.integers(0, n * ppn, size=(n, 16)).astype(np.int32))
        reps = 3 if quick else 30
        rounds = steering.num_rounds(want.shape[1], ROUTE_BUDGET)
        page_bytes = pool.shape[1] * 4
        rec = recorder if recorder is not None else TraceRecorder()
        measured: dict = {}
        measured_unfused: dict = {}
        phase_ops: dict = {"fused": {}, "unfused": {}}
        with bridge.use_mesh(mesh):
            for c in PIPELINE_CHANNELS:
                for fused, acc in ((True, measured),
                                   (False, measured_unfused)):
                    key = "fused" if fused else "unfused"
                    pull = jax.jit(
                        lambda p, w, t, _c=c, _f=fused: bridge.pull_pages(
                            p, w, t, mesh=mesh, budget=ROUTE_BUDGET,
                            channels=_c, fused=_f))
                    compiled = pull.lower(pool, want, table).compile()
                    phase_ops[key][str(c)] = phase_op_counts(
                        compiled.as_text())
                    jax.block_until_ready(compiled(pool, want, table))
                    t0 = time.perf_counter()
                    with rec.span(f"transfer:pipeline_{key}_c{c}",
                                  scenario="pipeline", engine=key,
                                  channels=c, reps=reps):
                        for _ in range(reps):
                            r = compiled(pool, want, table)
                        rec.fence(r)
                    acc[str(c)] = round(
                        (time.perf_counter() - t0) / reps * 1e6, 1)
                    if samples is not None:
                        samples.append({
                            "scenario": "pipeline",
                            "name": f"pipeline_{key}_c{c}",
                            "features": [round(float(x), 6) for x in
                                         perfmodel.route_features(
                                             bi, page_bytes, ROUTE_BUDGET,
                                             rounds=rounds, channels=c)],
                            "measured_us": acc[str(c)]})
        out["measured_us_per_call"] = measured
        out["measured_unfused_us_per_call"] = measured_unfused
        out["phase_breakdown"] = _phase_breakdown(
            phase_ops, measured, measured_unfused)
        # Model-vs-measured shape error: both sweeps normalized to their
        # serial (channels=1) point, so the record tracks whether deeper
        # pipelines *scale* the way the model says they should — the PR 4
        # regression (measured wall-clock growing with depth while the
        # model predicts a mild win) shows up here as a large error, and
        # validate_bench.py bands the fused sweep itself.
        err = {str(c): round(abs(
            measured[str(c)] / measured["1"]
            - model[str(c)] / model["1"]), 3) for c in PIPELINE_CHANNELS}
        err["mean"] = round(sum(err.values()) / len(err), 3)
        out["model_vs_measured_error"] = err
    return out


def fused_section(quick: bool = False,
                  recorder: TraceRecorder | None = None,
                  samples: list | None = None) -> dict:
    """Fused vs unfused epoch wall-clock + lowered-datapath op counts.

    Times one jitted ``pull_pages`` epoch (2 rounds of budget 8) on the
    real 8-device ring with the fused Pallas datapath on and off, at the
    wire-bound (256 KiB) and latency-bound (4 KiB) page sizes.  Acceptance
    (validate_bench.py): fused beats unfused at **both** sizes — the fused
    engine collapses each round's 2*(N-1)*channels steering collectives
    to at most N (one request all_gather plus the payload exchange: an
    all_to_all on TPU, a ppermute hop per slot off-TPU) and drops the
    per-slot mask->gather->commit chain, so its win must not depend on
    the wire-bound regime.

    Methodology: the emulated ring timeshares one host (CI runs on a
    single core), so back-to-back config sweeps drift by double-digit
    percentages and whichever engine runs first in a fixed rotation eats a
    positional penalty (allocator/cache state left by the previous cycle).
    Each page size therefore times the two engines as interleaved pairs
    with the order flipped every repetition (ABBA) and records the
    per-engine **median** — ambient drift and the positional bias cancel
    instead of deciding the gate.  The ``hlo`` block counts intermediate
    ``copy`` ops and collectives in both lowered programs
    (benchmarks/hlo_analysis.py), making the dispatch-overhead claim
    inspectable rather than inferred.
    """
    n, ppn = ROUTE_NODES, 16
    out: dict = {"source": "model-only", "page_sweep": {}}
    if jax.device_count() < n:
        return out
    out["source"] = f"{n}-device ring"
    mesh = jax.make_mesh((n,), ("data",))
    rng = np.random.default_rng(11)
    table = MemPortTable.striped(n * ppn, n, ppn)
    want = jnp.asarray(
        rng.integers(0, n * ppn, size=(n, 16)).astype(np.int32))
    reps = 10 if quick else 24
    rec = recorder if recorder is not None else TraceRecorder()
    rounds = steering.num_rounds(want.shape[1], ROUTE_BUDGET)
    bi = steering.bidirectional_program(n)
    with bridge.use_mesh(mesh):
        for label, page_bytes in FUSED_PAGE_SIZES.items():
            pool = jnp.asarray(rng.normal(
                size=(n * ppn, page_bytes // 4)).astype(np.float32))
            entry: dict = {"page_bytes": page_bytes}
            pulls, times = {}, {}
            for fused in (True, False):
                pulls[fused] = jax.jit(
                    lambda p, w, t, _f=fused: bridge.pull_pages(
                        p, w, t, mesh=mesh, budget=ROUTE_BUDGET, fused=_f))
                jax.block_until_ready(pulls[fused](pool, want, table))
                times[fused] = []
            with rec.span(f"transfer:fused_{label}", scenario="fused",
                          page_bytes=page_bytes, reps=reps) as sp:
                for rep in range(reps):
                    order = (True, False) if rep % 2 == 0 else (False, True)
                    for fused in order:
                        t0 = time.perf_counter()
                        jax.block_until_ready(
                            pulls[fused](pool, want, table))
                        times[fused].append(time.perf_counter() - t0)
            entry["fused_us"] = round(
                float(np.median(times[True])) * 1e6, 1)
            entry["unfused_us"] = round(
                float(np.median(times[False])) * 1e6, 1)
            entry["speedup"] = round(entry["unfused_us"]
                                     / max(entry["fused_us"], 1e-9), 2)
            rec.annotate(sp, fused_us=entry["fused_us"],
                         unfused_us=entry["unfused_us"])
            out["page_sweep"][label] = entry
            if samples is not None:
                # The only samples with non-trivial wire bytes: they make
                # the calibrator's us/MiB payload term identifiable.
                feats = [round(float(x), 6) for x in perfmodel.route_features(
                    bi, page_bytes, ROUTE_BUDGET, rounds=rounds)]
                for engine in ("fused", "unfused"):
                    samples.append({
                        "scenario": "fused",
                        "name": f"fused_{label}_{engine}",
                        "features": feats,
                        "measured_us": entry[f"{engine}_us"]})
        # Lowered-HLO structure at the latency-bound size (where dispatch
        # and copy overhead, not wire bytes, decide the epoch time).
        pool = jnp.asarray(rng.normal(
            size=(n * ppn, SMALL_PAGE_BYTES // 4)).astype(np.float32))
        hlo = {}
        for fused, key in ((True, "fused"), (False, "unfused")):
            text = jax.jit(lambda p, w, t, _f=fused: bridge.pull_pages(
                p, w, t, mesh=mesh, budget=ROUTE_BUDGET, fused=_f)).lower(
                    pool, want, table).compile().as_text()
            hlo[f"{key}_copies"] = hlo_analysis.count_ops(text, "copy")
            hlo[f"{key}_collectives"] = sum(
                hlo_analysis.count_ops(text, c)
                for c in hlo_analysis.COLLECTIVES)
        out["hlo"] = hlo
    return out


def _measure_composition(want, lane, table, program, n: int,
                         active_budget, recorder=None, label: str = "",
                         samples: list | None = None,
                         reps: int = 3) -> object:
    """Telemetry for one composed request matrix (real ring or oracle).

    On the real ring the composition is jitted, wall-clock timed inside a
    fenced trace span annotated with the per-tenant bridge counters, and
    (when ``samples`` is given) appended as a calibration sample.
    """
    if jax.device_count() >= n:
        ppn = 16
        mesh = jax.make_mesh((n,), ("data",))
        pool = jnp.zeros((n * ppn, 4), jnp.float32)
        rec = recorder if recorder is not None else TraceRecorder()
        with bridge.use_mesh(mesh):
            pull = jax.jit(lambda p, w, t, ab, tid: bridge.pull_pages(
                p, w, t, mesh=mesh, budget=ROUTE_BUDGET, program=program,
                active_budget=ab, collect_telemetry=True, tenant_ids=tid))
            args = (pool, jnp.asarray(want), table,
                    jnp.asarray(active_budget), jnp.asarray(lane))
            jax.block_until_ready(pull(*args))   # compile
            t0 = time.perf_counter()
            with rec.span(f"transfer:tenancy_{label or 'composition'}",
                          scenario="tenancy", composition=label,
                          reps=reps) as sp:
                for _ in range(reps):
                    r = pull(*args)
                rec.fence(r)
            dt_us = (time.perf_counter() - t0) / reps * 1e6
        _, telem = r
        rec.annotate_telemetry(
            sp, telem, page_bytes=pool.shape[1] * 4,
            tenant_names={0: "interactive", 1: "batch"})
        if samples is not None:
            rounds = steering.num_rounds(want.shape[1], ROUTE_BUDGET)
            samples.append({
                "scenario": "tenancy",
                "name": f"tenancy_{label or 'composition'}",
                "features": [round(float(x), 6) for x in
                             perfmodel.route_features(
                                 program, pool.shape[1] * 4, ROUTE_BUDGET,
                                 rounds=rounds)],
                "measured_us": round(dt_us, 1)})
        return telem
    return ref.expected_transfer_telemetry(
        want, table, program, num_nodes=n, budget=ROUTE_BUDGET,
        active_budget=active_budget, tenant_ids=lane)


def _interactive_completion_us(telem, program, n: int, last_idx: int,
                               total_len: int) -> float:
    """Completion latency of the interactive tenant's last request.

    A composition of ``total_len`` requests is served in
    ``num_rounds(total_len, budget)`` rounds of ``ROUTE_BUDGET`` lanes; the
    request at index ``last_idx`` retires when round
    ``ceil((last_idx + 1) / budget)`` completes, each round priced by the
    perfmodel under the composition's *measured* per-slot loads.
    """
    agg = TelemetryAggregator(n, page_bytes=ROUTE_PAGE_BYTES)
    agg.update(telem)
    rounds_total = steering.num_rounds(total_len, ROUTE_BUDGET)
    slot_pages = agg.distance_pages() / (n * rounds_total)
    round_us = perfmodel.predict_round_latency_us(
        program, ROUTE_PAGE_BYTES, ROUTE_BUDGET, slot_pages=slot_pages)
    return steering.num_rounds(last_idx + 1, ROUTE_BUDGET) * round_us


def tenancy_scenario(recorder: TraceRecorder | None = None,
                     samples: list | None = None) -> dict:
    """Interactive decode tenant vs a batch-pull noisy neighbour.

    Three compositions of the same offered load, measured (real 8-ring or
    oracle) and priced by the perfmodel under the measured loads:

    * **solo** — the interactive tenant alone: its 6 pages/node complete in
      one bridge round (the baseline its SLO is written against);
    * **naive FIFO** — no orchestration: the batch tenant's 40-page backlog
      is already queued ahead, so the interactive requests retire only when
      the last round of the combined 46-page list drains (degradation grows
      unboundedly with the backlog);
    * **QoS** — the orchestrator's weighted-fair schedule (3:1 shares)
      clips the batch tenant to its window and composes the interactive
      window first: the interactive pages again complete in round one,
      sharing it with only the batch window's pages.

    Acceptance (validate_bench.py): ``interactive_qos_us`` within 1.5x of
    ``interactive_solo_us`` while the naive ratio is strictly worse.
    """
    n, ppn = ROUTE_NODES, 16
    topo = Topology.boards(2, 4)
    cp = ControlPlane(num_nodes=n, pages_per_node=ppn, num_logical=n * ppn,
                      topology=topo)
    orc = Orchestrator(cp, budget=ROUTE_BUDGET, page_bytes=ROUTE_PAGE_BYTES,
                       control_period=1, migrate=False)
    orc.register(TenantSpec(0, "interactive", qos="interactive", share=3.0,
                            slo_round_us=1e5))
    orc.register(TenantSpec(1, "batch", qos="batch", share=1.0))
    inter_pages = sum(TENANCY_INTERACTIVE_PAGES.values())
    _, li = orc.request_lease(0, n * inter_pages)
    _, lb = orc.request_lease(1, n * (ppn - inter_pages) - n,
                              policy="striped")
    assert li is not None and lb is not None
    program = orc.route_program()

    # Interactive backlog: per node, pages homed at its near neighbours
    # (affinity placement put tenant 0's pages on board 0; re-key the
    # request lists off the actual table so distances are as designed).
    home = np.asarray(cp.table().home)
    inter_rows: list[list[int]] = []
    for i in range(n):
        row = []
        for d, count in TENANCY_INTERACTIVE_PAGES.items():
            h = (i + d) % n
            ids = [int(p) for p in li.region.page_ids if home[p] == h]
            row += ids[:count]
            # fabric may have spilled pages off the exact neighbour: fall
            # back to any of the tenant's pages to keep the load constant
        row += [int(p) for p in li.region.page_ids
                if int(p) not in row][: inter_pages - len(row)]
        inter_rows.append(row[:inter_pages])
    # Batch readers scan the whole leased region: each node's backlog
    # cycles over the lease's pages (pull is read-only, so repeated ids
    # across nodes are fine — it is a striped hot scan).
    bids = np.asarray(lb.region.page_ids, np.int64)
    batch_rows = [[int(bids[(i * 7 + k) % len(bids)])
                   for k in range(TENANCY_BATCH_BACKLOG)] for i in range(n)]

    source = ("oracle" if jax.device_count() < n else f"{n}-device ring")
    table = orc.table()

    # 1. solo: the interactive tenant alone, full budget.
    want_solo = np.full((n, inter_pages), -1, np.int32)
    for i, row in enumerate(inter_rows):
        want_solo[i, : len(row)] = row
    lane_solo = np.zeros_like(want_solo)
    telem_solo = _measure_composition(want_solo, lane_solo, table, program,
                                      n, np.full((n,), ROUTE_BUDGET,
                                                 np.int32),
                                      recorder=recorder, label="solo",
                                      samples=samples)
    solo_us = _interactive_completion_us(telem_solo, program, n,
                                         inter_pages - 1, inter_pages)

    # 2. naive FIFO: batch backlog queued ahead, no windows.
    naive_len = TENANCY_BATCH_BACKLOG + inter_pages
    want_naive = np.full((n, naive_len), -1, np.int32)
    lane_naive = np.zeros((n, naive_len), np.int32)
    for i in range(n):
        want_naive[i, :TENANCY_BATCH_BACKLOG] = batch_rows[i]
        lane_naive[i, :TENANCY_BATCH_BACKLOG] = 1
        want_naive[i, TENANCY_BATCH_BACKLOG:] = inter_rows[i]
    telem_naive = _measure_composition(want_naive, lane_naive, table,
                                       program, n,
                                       np.full((n,), ROUTE_BUDGET, np.int32),
                                       recorder=recorder, label="naive_fifo",
                                       samples=samples)
    naive_us = _interactive_completion_us(telem_naive, program, n,
                                          naive_len - 1, naive_len)

    # 3. QoS: the orchestrator's weighted-fair windows (interactive first).
    backlogs = {0: inter_rows, 1: batch_rows}
    want_qos, lane_qos, _ = orc.compose_requests(backlogs)
    telem_qos = _measure_composition(want_qos, lane_qos, table, program, n,
                                     orc.active_budget(),
                                     recorder=recorder, label="qos",
                                     samples=samples)
    windows = dict(orc.schedule.windows)
    qos_us = _interactive_completion_us(telem_qos, program, n,
                                        windows[0] - 1,
                                        want_qos.shape[1])
    orc.step(telem_qos)   # close the loop: measured demand re-fits windows

    served = np.asarray(telem_qos.tenant_served).sum(0)
    spilled = np.asarray(telem_qos.tenant_spilled).sum(0)
    return {
        "source": source,
        "interactive_pages": inter_pages,
        "batch_backlog_pages": TENANCY_BATCH_BACKLOG,
        "windows": {"interactive": windows[0], "batch": windows[1]},
        "refit_windows": {"interactive": orc.schedule.windows[0],
                          "batch": orc.schedule.windows[1]},
        "interactive_solo_us": round(solo_us, 2),
        "interactive_naive_us": round(naive_us, 2),
        "interactive_qos_us": round(qos_us, 2),
        "qos_isolation_ratio": round(qos_us / solo_us, 3),
        "naive_degradation_ratio": round(naive_us / solo_us, 3),
        "tenant_served": {"interactive": int(served[0]),
                          "batch": int(served[1])},
        "tenant_spilled": {"interactive": int(spilled[0]),
                           "batch": int(spilled[1])},
    }


def hierarchical_scenario(num_boards: int, board_size: int,
                          recorder: TraceRecorder | None = None) -> dict:
    """Flat-vs-hierarchical round latency under intra-board-heavy traffic.

    Builds the fabric, drives an intra-heavy request matrix (each endpoint
    pulls INTRA_PAGES from its board mates by local ring delta), measures
    the per-distance / per-tier loads — through the real datapath with
    ``collect_telemetry`` when enough devices exist, through the telemetry
    oracle otherwise (the simulated 16/32-endpoint racks) — and models one
    round under the measured loads for the topology-blind flat
    bidirectional schedule vs the two-tier hierarchical schedule.
    """
    topo = Topology.boards(num_boards, board_size)
    n, g = topo.num_nodes, board_size
    ppn = 16
    cp = ControlPlane(num_nodes=n, pages_per_node=ppn, num_logical=n * ppn,
                      topology=topo)
    cp.allocate(n * ppn, policy="striped")   # page p -> home p % n
    table = cp.table()
    want_rows = []
    for i in range(n):
        row, l_i, base = [], i % g, (i // g) * g
        for dl, count in INTRA_PAGES.items():
            if dl >= g:
                continue
            h = base + (l_i + dl) % g
            row += [h + n * k for k in range(count)]
        want_rows.append(row)
    want = np.asarray(want_rows, np.int32)
    rounds = steering.num_rounds(want.shape[1], ROUTE_BUDGET)

    source = "oracle"
    bi = steering.bidirectional_program(n)
    if jax.device_count() >= n:
        source = f"{n}-device ring"
        mesh = jax.make_mesh((n,), ("data",))
        pool = jnp.zeros((n * ppn, 4), jnp.float32)
        rec = recorder if recorder is not None else TraceRecorder()
        with bridge.use_mesh(mesh):
            with rec.span(f"transfer:hierarchical_{num_boards}x{board_size}",
                          scenario="hierarchical", boards=num_boards,
                          board_size=board_size) as sp:
                _, telem = bridge.pull_pages(
                    pool, jnp.asarray(want), table, mesh=mesh,
                    budget=ROUTE_BUDGET, topology=topo,
                    collect_telemetry=True)
                rec.fence(telem)
        rec.annotate_telemetry(sp, telem, page_bytes=pool.shape[1] * 4)
    else:
        telem = ref.expected_transfer_telemetry(
            want, table, bi, num_nodes=n, budget=ROUTE_BUDGET, topology=topo)

    agg = TelemetryAggregator(n, page_bytes=ROUTE_PAGE_BYTES)
    agg.update(telem)
    slot_pages = agg.distance_pages() / (n * rounds)
    slot_intra = agg.distance_intra_pages() / (n * rounds)
    live = agg.live_distances()
    hier = cp.route_program(telemetry=agg)
    steering.validate_hierarchical(hier, topo)
    flat = steering.pruned_program(bi, live)
    kw = dict(slot_pages=slot_pages, topology=topo,
              slot_intra_pages=slot_intra)
    lat_flat = perfmodel.predict_round_latency_us(
        flat, ROUTE_PAGE_BYTES, ROUTE_BUDGET, **kw)
    lat_hier = perfmodel.predict_round_latency_us(
        hier, ROUTE_PAGE_BYTES, ROUTE_BUDGET, **kw)
    stats_h = perfmodel.hierarchical_route_stats(hier, topo)
    stats_f = perfmodel.hierarchical_route_stats(flat, topo)
    return {
        "source": source,
        "num_boards": num_boards,
        "board_size": board_size,
        "intra_pages": {str(d): c for d, c in INTRA_PAGES.items() if d < g},
        "bytes_per_round": perfmodel.predict_round_bytes(
            hier, ROUTE_PAGE_BYTES, ROUTE_BUDGET, slot_pages=slot_pages),
        "board_hops_flat": stats_f["board_hops"],
        "board_hops_hier": stats_h["board_hops"],
        "flat_bidirectional_us": round(lat_flat, 2),
        "hierarchical_us": round(lat_hier, 2),
    }


def calibration_section(samples: list, cp: ControlPlane,
                        agg: TelemetryAggregator) -> dict:
    """Fit the online perfmodel calibrator on the measured-scenario samples.

    Every wall-clock sample collected by the skewed / pipeline / tenancy
    scenarios is a ``(route-feature vector, measured us)`` pair; CAL_EPOCHS
    deterministic RLS passes fit the linearized analytic model's constants
    (per-tier hop RTTs, payload us/MiB, per-chunk and per-transfer
    overhead) to what this backend actually ran.  The record compares the
    static-prior prediction against the fitted one per sample and per
    scenario — ``validate_bench.py`` gates fitted <= static, i.e. the
    measure->fit->steer loop must beat the datasheet constants on its own
    training regime before anyone trusts it to steer.  The fitted
    calibrator then re-runs the control plane's pipeline-depth pick so the
    steering consequence (dispatch-dominated backend -> stay serial) is
    recorded next to the constants that caused it.
    """
    out: dict = {"source": "model-only",
                 "feature_names": list(perfmodel.FEATURE_NAMES),
                 "epochs": CAL_EPOCHS}
    if not samples:
        return out
    out["source"] = f"{ROUTE_NODES}-device ring"
    cal = perfmodel.Calibrator()
    for _ in range(CAL_EPOCHS):
        for s in samples:
            cal.observe(s["features"], s["measured_us"])
    rows_out = []
    per_scen: dict[str, list[tuple[float, float]]] = {}
    for s in samples:
        m = float(s["measured_us"])
        static_us = cal.static_predict_us(s["features"])
        fitted_us = cal.predict_us(s["features"])
        se = abs(static_us - m) / max(m, 1e-9)
        fe = abs(fitted_us - m) / max(m, 1e-9)
        rows_out.append({**s, "static_us": round(static_us, 1),
                         "fitted_us": round(fitted_us, 1),
                         "static_err": round(se, 4),
                         "fitted_err": round(fe, 4)})
        per_scen.setdefault(s["scenario"], []).append((se, fe))
    err = {scen: {"static": round(sum(e[0] for e in v) / len(v), 4),
                  "fitted": round(sum(e[1] for e in v) / len(v), 4)}
           for scen, v in sorted(per_scen.items())}
    flat = [e for v in per_scen.values() for e in v]
    err["overall"] = {
        "static": round(sum(e[0] for e in flat) / len(flat), 4),
        "fitted": round(sum(e[1] for e in flat) / len(flat), 4)}
    out["constants"] = cal.constants()
    out["samples"] = rows_out
    out["model_vs_measured_error"] = err
    out["selected_channels"] = {
        "static": {
            "wire_bound_256KiB": cp.select_channels(
                ROUTE_BUDGET, ROUTE_PAGE_BYTES, telemetry=agg),
            "latency_bound_4KiB": cp.select_channels(
                ROUTE_BUDGET, SMALL_PAGE_BYTES, telemetry=agg)},
        "calibrated": {
            "wire_bound_256KiB": cp.select_channels(
                ROUTE_BUDGET, ROUTE_PAGE_BYTES, telemetry=agg,
                calibrator=cal),
            "latency_bound_4KiB": cp.select_channels(
                ROUTE_BUDGET, SMALL_PAGE_BYTES, telemetry=agg,
                calibrator=cal)},
    }
    return out


def alerts_section() -> dict:
    """Sentinel drill: zero false positives clean, catches a 2x injection.

    Drives an orchestrated 8-ring through a clean phase whose measured
    round latencies are exactly the calibrator's own prediction (residuals
    ~0, ratios ~1 — any alert here is a false positive), then injects a
    sustained 2x latency regression and counts the samples until the
    sentinel's windowed-median detector fires.  ``validate_bench.py``
    gates clean_alerts == 0, regression_alerts >= 1 and detection within
    one window.  The orchestrator's debug bundle (flight journal +
    metrics + state) lands in ``BENCH_debug_bundle.zip``.
    """
    cp = ControlPlane(num_nodes=ROUTE_NODES, pages_per_node=16,
                      num_logical=ROUTE_NODES * 16)
    orc = Orchestrator(cp, budget=ROUTE_BUDGET, page_bytes=ROUTE_PAGE_BYTES,
                       control_period=4, migrate=False)
    orc.register(TenantSpec(0, "drill", qos="interactive"))
    orc.request_lease(0, ROUTE_NODES * 4)
    window = orc.sentinel.window
    clean_rounds = window + 8
    for _ in range(clean_rounds):
        feats = perfmodel.route_features(
            orc.route_program(), orc.page_bytes, orc.budget,
            channels=orc.channels)
        orc.step(measured_round_us=orc.calibrator.predict_us(feats))
    clean_alerts = len(orc.sentinel.alerts)
    detect_samples = 0
    for i in range(2 * window):
        feats = perfmodel.route_features(
            orc.route_program(), orc.page_bytes, orc.budget,
            channels=orc.channels)
        orc.step(measured_round_us=2.0 * orc.calibrator.predict_us(feats))
        if len(orc.sentinel.alerts) > clean_alerts:
            detect_samples = i + 1
            break
    orc.dump_debug_bundle(str(BUNDLE_ZIP))
    return {
        "source": f"{ROUTE_NODES}-node orchestrated drill",
        "window": window,
        "clean_rounds": clean_rounds,
        "clean_alerts": clean_alerts,
        "regression_alerts": len(orc.sentinel.alerts) - clean_alerts,
        "detect_samples": detect_samples,
        "alert_kinds": sorted({a.kind for a in orc.sentinel.alerts}),
    }


def rows(quick: bool = False) -> list[str]:
    out = []
    total = sum(perfmodel.RTT_PIPELINE_CYCLES.values())
    for stage, cyc in perfmodel.RTT_PIPELINE_CYCLES.items():
        ns = cyc / perfmodel.PAPER_HW.clock_mhz * 1e3
        out.append(f"rtt_stage_{stage.split('(')[0].strip().replace(' ', '_')},"
                   f"0,{cyc}cyc={ns:.0f}ns")
    out.append(f"rtt_total,0,{total}cyc={total/perfmodel.PAPER_HW.clock_mhz*1e3:.0f}ns"
               f" (paper: 134cyc=800ns)")

    us = measure_sw_pull_us(reps=5 if quick else 50)
    out.append(f"bridge_sw_pull_1page,{us:.1f},loopback_jitted")

    # modelled TPU pull-mode page latency (1 hop, 256 KiB page)
    lat_us = (2 * perfmodel.TPU_HW.ici_hop_latency_us
              + (1 << 18) / (perfmodel.TPU_HW.ici_link_gbps * 1e9) * 1e6)
    out.append(f"bridge_tpu_page_rtt_model,0,{lat_us:.1f}us_per_256KiB_page")
    bw = perfmodel.tpu_remote_page_bandwidth_gbps(1 << 18)
    out.append(f"bridge_tpu_pull_bandwidth_model,0,{bw:.1f}GB/s_per_pair")

    # route-program schedule variants (the software-defined circuit plane)
    bench: dict[str, dict] = {"sw_pull_1page_us": round(us, 2),
                              "num_nodes": ROUTE_NODES,
                              "page_bytes": ROUTE_PAGE_BYTES,
                              "budget": ROUTE_BUDGET, "variants": {}}
    # Every measured scenario below runs inside this recorder's fenced
    # spans (written to BENCH_trace.json) and feeds the calibration
    # samples the online perfmodel fit consumes at the end.
    recorder = TraceRecorder(process_name="bench:bridge_latency")
    cal_samples: list[dict] = []
    # the measured closed loop: skew -> telemetry -> load-balanced program
    measured, lb_prog, skew_agg, skew_cp = skewed_traffic_scenario(
        recorder=recorder, samples=cal_samples, quick=quick)
    variants = dict(route_variants())
    variants["load_balanced"] = lb_prog
    for name, prog in variants.items():
        stats = perfmodel.route_epoch_stats(prog)
        model_us = perfmodel.predict_round_latency_us(
            prog, ROUTE_PAGE_BYTES, ROUTE_BUDGET)
        model_us_nobuf = perfmodel.predict_round_latency_us(
            prog, ROUTE_PAGE_BYTES, ROUTE_BUDGET, edge_buffer=False)
        bytes_per_round = stats["live_slots"] * ROUTE_BUDGET * ROUTE_PAGE_BYTES
        out.append(
            f"bridge_route_{name},0,epochs={stats['num_epochs']}"
            f" slots={stats['live_slots']} hops={stats['total_hops']}"
            f" round_model={model_us:.0f}us")
        bench["variants"][name] = {
            "epochs": stats["num_epochs"],
            "live_slots": stats["live_slots"],
            "total_hops": stats["total_hops"],
            "bytes_per_round": bytes_per_round,
            "model_round_us": round(model_us, 2),
            "model_round_us_bufferless": round(model_us_nobuf, 2),
        }
    bench["measured"] = measured
    out.append(
        f"bridge_route_measured,0,source={measured['source']}"
        f" static_bi={measured['static_bidirectional_us']}us"
        f" load_balanced={measured['load_balanced_us']}us")
    # pipelined multi-channel round engine: depth sweep + control-plane pick
    pipe = pipeline_sweep(skew_agg, skew_cp, quick=quick,
                          recorder=recorder, samples=cal_samples)
    bench["pipeline"] = pipe
    sweep = " ".join(f"c{c}={pipe['model_round_us'][str(c)]}us"
                     for c in PIPELINE_CHANNELS)
    out.append(
        f"bridge_pipeline_sweep,0,source={pipe['source']} {sweep}"
        f" picks={pipe['selected_channels']}")
    # fused vs unfused epoch wall-clock (the Pallas datapath claim)
    fus = fused_section(quick=quick, recorder=recorder,
                        samples=cal_samples)
    bench["fused"] = fus
    FUSED_JSON.write_text(json.dumps(fus, indent=2) + "\n")
    if fus["page_sweep"]:
        cmp_str = " ".join(
            f"{label}:{e['fused_us']}us_vs_{e['unfused_us']}us"
            f"(x{e['speedup']})" for label, e in fus["page_sweep"].items())
        out.append(f"bridge_fused_epoch,0,source={fus['source']} {cmp_str}")
    else:
        out.append(f"bridge_fused_epoch,0,source={fus['source']}")
    # flat ring vs board + rack fabric (8 real endpoints, 16/32 simulated)
    bench["hierarchical"] = {}
    for label, (boards, size) in HIER_FABRICS.items():
        h = hierarchical_scenario(boards, size, recorder=recorder)
        bench["hierarchical"][label] = h
        out.append(
            f"bridge_hier_{label},0,{boards}x{size} source={h['source']}"
            f" flat_bi={h['flat_bidirectional_us']}us"
            f" hier={h['hierarchical_us']}us")
    # multi-tenant co-location: QoS windows vs naive FIFO sharing
    ten = tenancy_scenario(recorder=recorder, samples=cal_samples)
    bench["tenancy"] = ten
    out.append(
        f"bridge_tenancy,0,source={ten['source']}"
        f" solo={ten['interactive_solo_us']}us"
        f" qos={ten['interactive_qos_us']}us"
        f" (x{ten['qos_isolation_ratio']})"
        f" naive={ten['interactive_naive_us']}us"
        f" (x{ten['naive_degradation_ratio']})")
    # online calibration: fit the perfmodel constants to what actually ran
    cal = calibration_section(cal_samples, skew_cp, skew_agg)
    bench["calibration"] = cal
    if "model_vs_measured_error" in cal:
        e = cal["model_vs_measured_error"]["overall"]
        out.append(
            f"bridge_calibration,0,source={cal['source']}"
            f" samples={len(cal['samples'])}"
            f" err_static={e['static']} err_fitted={e['fitted']}"
            f" picks={cal['selected_channels']['calibrated']}")
    else:
        out.append(f"bridge_calibration,0,source={cal['source']}")
    # sentinel drill: clean run stays silent, injected 2x regression caught
    al = alerts_section()
    bench["alerts"] = al
    out.append(
        f"bridge_alerts,0,source={al['source']}"
        f" clean={al['clean_alerts']} regression={al['regression_alerts']}"
        f" detect_samples={al['detect_samples']}/{al['window']}"
        f" kinds={','.join(al['alert_kinds'])}")
    out.append(f"bridge_debug_bundle,0,{BUNDLE_ZIP.name}")
    BENCH_JSON.write_text(json.dumps(bench, indent=2) + "\n")
    out.append(f"bridge_route_json,0,{BENCH_JSON.name}")
    recorder.write(str(TRACE_JSON))
    out.append(f"bridge_trace,0,{TRACE_JSON.name}"
               f" spans={len(recorder.spans)} (https://ui.perfetto.dev)")
    return out


def run(quick: bool = False) -> list[str]:
    return rows(quick=quick)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer timing reps (CI smoke job)")
    for r in run(quick=ap.parse_args().quick):
        print(r)
