"""Paper §3 latency reproduction: the 134-cycle / 800 ns round trip.

Reports the stage-by-stage pipeline budget (design partition), checks it
sums to the published total, and measures the *software* path length of our
bridge datapath (translation -> steering -> epochs) in ops/epochs per pull,
which is the TPU-side analogue of the cycle count.

Also compares route-program schedule variants (unidirectional /
bidirectional / pruned): circuit epochs, wired slots, bytes per round and
the analytical round latency from ``repro.core.perfmodel``.

Emits CSV rows: name,us_per_call,derived — and writes the same data
machine-readably to ``BENCH_bridge.json`` at the repo root so the perf
trajectory is tracked across PRs.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bridge, perfmodel, steering
from repro.core.memport import MemPortTable

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_bridge.json"

# Route-program comparison geometry: an 8-node mem ring moving 256 KiB pages
# in rounds of 8; "pruned" keeps the three distances a blocked/affinity
# placement typically exercises.
ROUTE_NODES = 8
ROUTE_PAGE_BYTES = 1 << 18
ROUTE_BUDGET = 8


def route_variants() -> dict[str, steering.RouteProgram]:
    bi = steering.bidirectional_program(ROUTE_NODES)
    return {
        "unidirectional": steering.unidirectional_program(ROUTE_NODES),
        "bidirectional": bi,
        "pruned": steering.pruned_program(bi, [1, 2, 6]),
    }


def measure_sw_pull_us() -> float:
    """One-page pull latency through the loopback bridge (jitted)."""
    table = MemPortTable.striped(16, 4, 4)
    pool = jnp.asarray(np.random.default_rng(0).normal(
        size=(16, 256)).astype(np.float32))
    want = jnp.asarray([[3]], jnp.int32)
    pull = jax.jit(lambda p, w, t: bridge.pull_pages(
        p, w, t, mesh=None, budget=1, table_nodes=4))
    jax.block_until_ready(pull(pool, want, table))  # compile
    t0 = time.perf_counter()
    reps = 50
    for _ in range(reps):
        r = pull(pool, want, table)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps * 1e6


def rows() -> list[str]:
    out = []
    total = sum(perfmodel.RTT_PIPELINE_CYCLES.values())
    for stage, cyc in perfmodel.RTT_PIPELINE_CYCLES.items():
        ns = cyc / perfmodel.PAPER_HW.clock_mhz * 1e3
        out.append(f"rtt_stage_{stage.split('(')[0].strip().replace(' ', '_')},"
                   f"0,{cyc}cyc={ns:.0f}ns")
    out.append(f"rtt_total,0,{total}cyc={total/perfmodel.PAPER_HW.clock_mhz*1e3:.0f}ns"
               f" (paper: 134cyc=800ns)")

    us = measure_sw_pull_us()
    out.append(f"bridge_sw_pull_1page,{us:.1f},loopback_jitted")

    # modelled TPU pull-mode page latency (1 hop, 256 KiB page)
    lat_us = (2 * perfmodel.TPU_HW.ici_hop_latency_us
              + (1 << 18) / (perfmodel.TPU_HW.ici_link_gbps * 1e9) * 1e6)
    out.append(f"bridge_tpu_page_rtt_model,0,{lat_us:.1f}us_per_256KiB_page")
    bw = perfmodel.tpu_remote_page_bandwidth_gbps(1 << 18)
    out.append(f"bridge_tpu_pull_bandwidth_model,0,{bw:.1f}GB/s_per_pair")

    # route-program schedule variants (the software-defined circuit plane)
    bench: dict[str, dict] = {"sw_pull_1page_us": round(us, 2),
                              "num_nodes": ROUTE_NODES,
                              "page_bytes": ROUTE_PAGE_BYTES,
                              "budget": ROUTE_BUDGET, "variants": {}}
    for name, prog in route_variants().items():
        stats = perfmodel.route_epoch_stats(prog)
        model_us = perfmodel.predict_round_latency_us(
            prog, ROUTE_PAGE_BYTES, ROUTE_BUDGET)
        model_us_nobuf = perfmodel.predict_round_latency_us(
            prog, ROUTE_PAGE_BYTES, ROUTE_BUDGET, edge_buffer=False)
        bytes_per_round = stats["live_slots"] * ROUTE_BUDGET * ROUTE_PAGE_BYTES
        out.append(
            f"bridge_route_{name},0,epochs={stats['num_epochs']}"
            f" slots={stats['live_slots']} hops={stats['total_hops']}"
            f" round_model={model_us:.0f}us")
        bench["variants"][name] = {
            "epochs": stats["num_epochs"],
            "live_slots": stats["live_slots"],
            "total_hops": stats["total_hops"],
            "bytes_per_round": bytes_per_round,
            "model_round_us": round(model_us, 2),
            "model_round_us_bufferless": round(model_us_nobuf, 2),
        }
    BENCH_JSON.write_text(json.dumps(bench, indent=2) + "\n")
    out.append(f"bridge_route_json,0,{BENCH_JSON.name}")
    return out


def run() -> list[str]:
    return rows()


if __name__ == "__main__":
    for r in run():
        print(r)
