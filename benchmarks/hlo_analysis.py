"""HLO-text analyzer: FLOPs, HBM bytes and collective bytes with while-loop
trip-count multipliers.

XLA's ``compiled.cost_analysis()`` visits every while body exactly ONCE
(verified empirically), so for scan-over-layers models it undercounts by the
trip count.  This analyzer parses the optimized HLO text, builds the
computation call graph (while / fusion / call / conditional), reads trip
counts from the ``backend_config={"known_trip_count":{"n":...}}`` attribute
XLA attaches to counted loops, and multiplies each computation's
contribution accordingly.

Counted quantities:
  flops            — dot / convolution FLOPs (2 * prod(out) * contraction)
  hbm_bytes        — operand + result bytes of *top-level* instructions
                     (instructions inside fusion computations are fused:
                     their traffic is the fusion op's operands/results)
  collective_bytes — result bytes of all-gather / all-reduce /
                     reduce-scatter / all-to-all / collective-permute /
                     ragged-all-to-all, with a per-op-kind breakdown
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, Optional

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")
SKIP_HBM_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                "bitcast", "while", "call", "conditional", "copy-start",
                "copy-done", "after-all", "partition-id", "replica-id",
                "iota"}

_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
    r"((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"([\w\-]+)\(")
_OPERAND = re.compile(r"%([\w\.\-]+)")
_CALL_ATTR = re.compile(
    r"(?:body|condition|calls|to_apply)=%?([\w\.\-]+)"
    r"|branch_computations=\{([^}]*)\}")
_TRIP = re.compile(r'known_trip_count[^0-9]*?"n":"(\d+)"')


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in SHAPE_RE.findall(shape_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def shape_elems(shape_str: str) -> int:
    m = SHAPE_RE.search(shape_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclass
class Instruction:
    name: str
    opcode: str
    result_shape: str
    result_bytes: int
    operands: list
    raw: str


@dataclass
class Computation:
    name: str
    instructions: list = field(default_factory=list)
    defs: dict = field(default_factory=dict)   # name -> shape string
    is_fused: bool = False

    def hbm_traffic(self) -> float:
        """Estimated real HBM bytes for one execution of this computation
        as a *fusion body*: params are reads (slice-aware), root is the
        write (update-aware for DUS roots)."""
        consumers: dict[str, list] = {}
        for ins in self.instructions:
            for op in ins.operands:
                consumers.setdefault(op, []).append(ins)
        total = 0.0
        root = self.instructions[-1] if self.instructions else None
        for ins in self.instructions:
            if ins.opcode != "parameter":
                continue
            users = consumers.get(ins.name, [])
            if users and all(u.opcode in ("dynamic-slice", "gather")
                             and u.operands and u.operands[0] == ins.name
                             for u in users):
                total += sum(u.result_bytes for u in users)
            elif users and all(
                    u.opcode == "dynamic-update-slice"
                    and u.operands and u.operands[0] == ins.name
                    for u in users):
                # buffer param of an in-place DUS: traffic = update bytes
                total += sum(shape_bytes(self.defs.get(u.operands[1], ""))
                             for u in users)
            else:
                total += shape_bytes(self.defs.get(ins.name, ""))
        if root is not None:
            if root.opcode == "dynamic-update-slice" and len(root.operands) > 1:
                total += shape_bytes(self.defs.get(root.operands[1], ""))
            else:
                total += root.result_bytes
        return total


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if line and not line[0].isspace() and line.rstrip().endswith("{"):
            hm = _COMP_HEADER.match(line)
            if hm:
                is_entry, name = hm.group(1), hm.group(2)
                cur = Computation(name="ENTRY" if is_entry else name)
                comps[cur.name] = cur
                continue
        if cur is None:
            continue
        im = _INSTR.match(line)
        if not im:
            continue
        name, shape_str, opcode = im.groups()
        rest = line[im.end():]
        # operands: %refs before attribute section (first "), " or ")," )
        head = rest.split("),")[0] if ")," in rest else rest
        opnames = [m.group(1) for m in _OPERAND.finditer(head)]
        instr = Instruction(name=name, opcode=opcode, result_shape=shape_str,
                            result_bytes=shape_bytes(shape_str),
                            operands=opnames, raw=line)
        cur.defs[name] = shape_str
        cur.instructions.append(instr)
    return comps


def _dot_flops(comp: Computation, ins: Instruction) -> int:
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.raw)
    if not m or not ins.operands:
        return 0
    lhs_shape = comp.defs.get(ins.operands[0], "")
    sm = SHAPE_RE.search(lhs_shape)
    if not sm:
        return 0
    dims = [int(d) for d in sm.group(2).split(",") if d]
    contract = 1
    for ci in m.group(1).split(","):
        if ci and int(ci) < len(dims):
            contract *= dims[int(ci)]
    return 2 * shape_elems(ins.result_shape) * contract


def _instr_hbm_bytes(comps: Dict[str, "Computation"], comp: "Computation",
                     ins: Instruction) -> float:
    """Slice-aware HBM traffic of one top-level instruction."""
    op = ins.opcode
    if op == "fusion":
        cm = re.search(r"calls=%?([\w\.\-]+)", ins.raw)
        if cm and cm.group(1) in comps:
            return comps[cm.group(1)].hbm_traffic()
        # fall through to generic accounting
    if op in ("dynamic-slice", "gather"):
        return 2.0 * ins.result_bytes
    if op == "dynamic-update-slice":
        upd = shape_bytes(comp.defs.get(ins.operands[1], "")) \
            if len(ins.operands) > 1 else ins.result_bytes
        return 3.0 * upd
    if op == "scatter":
        upd = shape_bytes(comp.defs.get(ins.operands[2], "")) \
            if len(ins.operands) > 2 else ins.result_bytes
        return 3.0 * upd
    operand_bytes = sum(shape_bytes(comp.defs.get(o, ""))
                        for o in ins.operands)
    return operand_bytes + float(ins.result_bytes)


@dataclass
class HloStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    by_collective: dict = field(default_factory=dict)
    unknown_trip_counts: int = 0
    top_collectives: list = field(default_factory=list)

    def as_dict(self) -> dict:
        return {"flops": self.flops, "hbm_bytes": self.hbm_bytes,
                "collective_bytes": self.collective_bytes,
                "by_collective": dict(self.by_collective),
                "unknown_trip_counts": self.unknown_trip_counts,
                "top_collectives": self.top_collectives[:20]}


def analyze(text: str) -> HloStats:
    comps = parse_hlo(text)
    stats = HloStats(by_collective=defaultdict(float))

    # computation multipliers from the call graph
    mult: Dict[str, float] = defaultdict(float)
    entry = comps.get("ENTRY") or next(iter(comps.values()))
    mult[entry.name] = 1.0
    changed, iters = True, 0
    while changed and iters < 100:
        changed, iters = False, iters + 1
        for cname, comp in comps.items():
            base = mult.get(cname, 0.0)
            if base == 0.0:
                continue
            for ins in comp.instructions:
                trips = 1.0
                if ins.opcode == "while":
                    tm = _TRIP.search(ins.raw)
                    if tm:
                        trips = float(tm.group(1))
                    else:
                        stats.unknown_trip_counts += 1
                callees = []
                for cm in _CALL_ATTR.finditer(ins.raw):
                    single, multi = cm.groups()
                    if single:
                        callees.append(single)
                    elif multi:
                        callees += [s.strip().lstrip("%")
                                    for s in multi.split(",")]
                for cn in callees:
                    if cn not in comps:
                        continue
                    factor = trips if ins.opcode == "while" else 1.0
                    newv = base * factor
                    if mult[cn] < newv:
                        mult[cn] = newv
                        changed = True
                if ins.opcode == "fusion":
                    for cm in re.finditer(r"calls=%?([\w\.\-]+)", ins.raw):
                        if cm.group(1) in comps:
                            comps[cm.group(1)].is_fused = True

    coll_items = []
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0 or comp.is_fused:
            # fused computations: traffic accounted at the fusion op; dots
            # inside fusions still count FLOPs below via the fused pass
            if m == 0.0:
                continue
        for ins in comp.instructions:
            if ins.opcode in ("dot", "convolution"):
                stats.flops += m * _dot_flops(comp, ins)
            if not comp.is_fused and ins.opcode not in SKIP_HBM_OPS:
                stats.hbm_bytes += m * _instr_hbm_bytes(comps, comp, ins)
            if any(ins.opcode.startswith(c) for c in COLLECTIVES) \
                    and not ins.opcode.endswith(("-start", "-done")):
                nbytes = m * ins.result_bytes
                stats.by_collective[ins.opcode] = (
                    stats.by_collective.get(ins.opcode, 0.0) + nbytes)
                stats.collective_bytes += nbytes
                coll_items.append((nbytes, ins.opcode, ins.result_shape, m))
            elif ins.opcode.endswith("-start") and any(
                    ins.opcode.startswith(c) for c in COLLECTIVES):
                # async collectives: count the -start op
                nbytes = m * ins.result_bytes
                kind = ins.opcode[:-6]
                stats.by_collective[kind] = (
                    stats.by_collective.get(kind, 0.0) + nbytes)
                stats.collective_bytes += nbytes
                coll_items.append((nbytes, kind, ins.result_shape, m))
    coll_items.sort(reverse=True)
    stats.top_collectives = [
        {"bytes": b, "op": o, "shape": s[:80], "mult": mm}
        for b, o, s, mm in coll_items[:20]]
    return stats


def count_ops(text: str, opcode: str) -> int:
    """Count instructions whose opcode starts with ``opcode``, across every
    computation (fusion bodies included).  Used by the bench suite to flag
    intermediate ``copy`` ops and collective counts in lowered datapaths."""
    comps = parse_hlo(text)
    return sum(1 for comp in comps.values() for ins in comp.instructions
               if ins.opcode.startswith(opcode))


def analyze_compiled(compiled) -> HloStats:
    return analyze(compiled.as_text())
