"""HLO-text analyzer: FLOPs, HBM bytes and collective bytes with while-loop
trip-count multipliers.

XLA's ``compiled.cost_analysis()`` visits every while body exactly ONCE
(verified empirically), so for scan-over-layers models it undercounts by the
trip count.  This analyzer parses the optimized HLO text, builds the
computation call graph (while / fusion / call / conditional), reads trip
counts from the ``backend_config={"known_trip_count":{"n":...}}`` attribute
XLA attaches to counted loops, and multiplies each computation's
contribution accordingly.

The text parser itself (computations, call graph, trip counts, shape
byte-widths) lives in :mod:`repro.analysis.hlo` and is shared with the
datapath auditor; this module keeps the FLOPs/HBM/collective *accounting*.

Counted quantities:
  flops            — dot / convolution FLOPs (2 * prod(out) * contraction)
  hbm_bytes        — operand + result bytes of *top-level* instructions
                     (instructions inside fusion computations are fused:
                     their traffic is the fusion op's operands/results)
  collective_bytes — result bytes of all-gather / all-reduce /
                     reduce-scatter / all-to-all / collective-permute /
                     ragged-all-to-all, with a per-op-kind breakdown
"""
from __future__ import annotations

import pathlib
import re
import sys
from collections import defaultdict
from dataclasses import dataclass, field

try:
    from repro.analysis import hlo as _hlo
except ImportError:  # invoked without PYTHONPATH=src (e.g. plain script run)
    sys.path.insert(
        0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
    from repro.analysis import hlo as _hlo

# Re-exports: the parser moved to repro.analysis.hlo; benchmarks and tests
# keep importing these names from here.
DTYPE_BYTES = _hlo.DTYPE_BYTES
SHAPE_RE = _hlo.SHAPE_RE
COLLECTIVES = _hlo.COLLECTIVES
SKIP_HBM_OPS = _hlo.SKIP_HBM_OPS
_COMP_HEADER = _hlo._COMP_HEADER
_INSTR = _hlo._INSTR
_OPERAND = _hlo._OPERAND
_CALL_ATTR = _hlo._CALL_ATTR
_TRIP = _hlo._TRIP
shape_bytes = _hlo.shape_bytes
shape_elems = _hlo.shape_elems
Instruction = _hlo.Instruction
Computation = _hlo.Computation
parse_hlo = _hlo.parse_hlo
count_ops = _hlo.count_ops


def _dot_flops(comp: Computation, ins: Instruction) -> int:
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.raw)
    if not m or not ins.operands:
        return 0
    lhs_shape = comp.defs.get(ins.operands[0], "")
    sm = SHAPE_RE.search(lhs_shape)
    if not sm:
        return 0
    dims = [int(d) for d in sm.group(2).split(",") if d]
    contract = 1
    for ci in m.group(1).split(","):
        if ci and int(ci) < len(dims):
            contract *= dims[int(ci)]
    return 2 * shape_elems(ins.result_shape) * contract


def _instr_hbm_bytes(comps, comp: Computation, ins: Instruction) -> float:
    """Slice-aware HBM traffic of one top-level instruction."""
    op = ins.opcode
    if op == "fusion":
        cm = re.search(r"calls=%?([\w\.\-]+)", ins.raw)
        if cm and cm.group(1) in comps:
            return comps[cm.group(1)].hbm_traffic()
        # fall through to generic accounting
    if op in ("dynamic-slice", "gather"):
        return 2.0 * ins.result_bytes
    if op == "dynamic-update-slice":
        upd = shape_bytes(comp.defs.get(ins.operands[1], "")) \
            if len(ins.operands) > 1 else ins.result_bytes
        return 3.0 * upd
    if op == "scatter":
        upd = shape_bytes(comp.defs.get(ins.operands[2], "")) \
            if len(ins.operands) > 2 else ins.result_bytes
        return 3.0 * upd
    operand_bytes = sum(shape_bytes(comp.defs.get(o, ""))
                        for o in ins.operands)
    return operand_bytes + float(ins.result_bytes)


@dataclass
class HloStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    by_collective: dict = field(default_factory=dict)
    unknown_trip_counts: int = 0
    top_collectives: list = field(default_factory=list)

    def as_dict(self) -> dict:
        return {"flops": self.flops, "hbm_bytes": self.hbm_bytes,
                "collective_bytes": self.collective_bytes,
                "by_collective": dict(self.by_collective),
                "unknown_trip_counts": self.unknown_trip_counts,
                "top_collectives": self.top_collectives[:20]}


def analyze(text: str) -> HloStats:
    comps = parse_hlo(text)
    stats = HloStats(by_collective=defaultdict(float))

    # computation multipliers from the call graph (shared walker; also
    # marks fusion bodies so their HBM traffic is charged at the fusion op)
    mult, stats.unknown_trip_counts = _hlo.call_multipliers(comps)

    coll_items = []
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0 or comp.is_fused:
            # fused computations: traffic accounted at the fusion op; dots
            # inside fusions still count FLOPs below via the fused pass
            if m == 0.0:
                continue
        for ins in comp.instructions:
            if ins.opcode in ("dot", "convolution"):
                stats.flops += m * _dot_flops(comp, ins)
            if not comp.is_fused and ins.opcode not in SKIP_HBM_OPS:
                stats.hbm_bytes += m * _instr_hbm_bytes(comps, comp, ins)
            if any(ins.opcode.startswith(c) for c in COLLECTIVES) \
                    and not ins.opcode.endswith(("-start", "-done")):
                nbytes = m * ins.result_bytes
                stats.by_collective[ins.opcode] = (
                    stats.by_collective.get(ins.opcode, 0.0) + nbytes)
                stats.collective_bytes += nbytes
                coll_items.append((nbytes, ins.opcode, ins.result_shape, m))
            elif ins.opcode.endswith("-start") and any(
                    ins.opcode.startswith(c) for c in COLLECTIVES):
                # async collectives: count the -start op
                nbytes = m * ins.result_bytes
                kind = ins.opcode[:-6]
                stats.by_collective[kind] = (
                    stats.by_collective.get(kind, 0.0) + nbytes)
                stats.collective_bytes += nbytes
                coll_items.append((nbytes, kind, ins.result_shape, m))
    coll_items.sort(reverse=True)
    stats.top_collectives = [
        {"bytes": b, "op": o, "shape": s[:80], "mult": mm}
        for b, o, s, mm in coll_items[:20]]
    return stats


def analyze_compiled(compiled) -> HloStats:
    return analyze(compiled.as_text())
