"""Benchmark harness: one module per paper table/figure + the roofline.

  fig3       — STREAM local vs disaggregated (paper Figure 3) + TPU projection
  latency    — 134-cycle RTT pipeline (paper §3) + bridge software path
  kv         — KV placements: local / bridge-pull / bridge-push
  roofline   — per (arch x shape) three-term roofline from the dry-run

Prints ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import bridge_latency, kv_placement, roofline, stream_fig3

    print("name,us_per_call,derived")
    for row in stream_fig3.run():
        print(row)
    for row in bridge_latency.run():
        print(row)
    for row in kv_placement.run():
        print(row)
    for row in roofline.run():
        print(row)


if __name__ == "__main__":
    sys.path.insert(0, ".")
    main()
