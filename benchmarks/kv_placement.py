"""Bridge KV placements head-to-head (the paper's Fig. 3, serving edition).

Measures one decode step of the same reduced model under local /
bridge_pull / bridge_push placements on CPU (wall time + correctness), and
derives the *modelled* pod-scale collective bytes per token for each mode —
the quantity the roofline shows is the pull-mode bottleneck.

Emits CSV rows: name,us_per_call,derived.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.config import RunConfig, ShapeConfig
from repro.models import transformer
from repro.serve import step as serve_step_mod

BATCH, MAX_LEN, PAGE_TOKENS, STEPS = 2, 64, 8, 8


def measured_rows() -> list[str]:
    cfg = dataclasses.replace(configs.get_reduced("granite-3-8b"),
                              dtype="float32")
    shape = ShapeConfig("bench", MAX_LEN, BATCH, "decode")
    params = transformer.init_params(cfg, jax.random.key(0))
    rows, outs = [], {}
    for kv in ("local", "bridge_pull", "bridge_push"):
        run = RunConfig(model=cfg, shape=shape, kv_placement=kv)
        cache_ops = serve_step_mod.make_cache_ops(
            run, mesh=None, max_len=MAX_LEN, page_tokens=PAGE_TOKENS,
            dtype=jnp.float32)
        state = serve_step_mod.init_serve_state(run, BATCH, cache_ops)
        step = jax.jit(serve_step_mod.build_serve_step(run, cache_ops),
                       donate_argnums=(1,))
        tokens = jnp.ones((BATCH,), jnp.int32)
        tokens, state = step(params, state, tokens)  # compile+warm
        t0 = time.perf_counter()
        seq = []
        for _ in range(STEPS):
            tokens, state = step(params, state, tokens)
            seq.append(np.asarray(tokens))
        jax.block_until_ready(tokens)
        us = (time.perf_counter() - t0) / STEPS * 1e6
        outs[kv] = np.stack(seq)
        rows.append(f"kv_decode_step_{kv},{us:.0f},cpu_reduced_model")
    same = (np.array_equal(outs["local"], outs["bridge_pull"])
            and np.array_equal(outs["local"], outs["bridge_push"]))
    rows.append(f"kv_decode_agreement,0,identical_tokens={same}")
    return rows


def modelled_rows() -> list[str]:
    """Pod-scale per-token collective bytes: pull vs push (gemma3 500k)."""
    cfg = configs.get_config("gemma3-12b")
    seq, b = 524_288, 1
    page_tokens = 512
    kv_bytes_per_token = 2 * cfg.num_kv_heads * cfg.head_dim * 2  # k+v bf16
    n_global_layers = sum(1 for k in cfg.layers if k == "global")
    pull = seq * kv_bytes_per_token * n_global_layers          # all pages move
    q_bytes = cfg.num_heads * cfg.head_dim * 4
    stats_bytes = (2 * cfg.num_heads + cfg.num_heads * cfg.head_dim) * 4
    push = (q_bytes + stats_bytes) * n_global_layers * 16      # x mem nodes
    return [
        f"kv_model_pull_bytes_per_token,0,{pull/2**30:.2f}GiB",
        f"kv_model_push_bytes_per_token,0,{push/2**20:.3f}MiB",
        f"kv_model_pull_over_push,0,{pull/push:.0f}x",
    ]


def run() -> list[str]:
    return measured_rows() + modelled_rows()


if __name__ == "__main__":
    for r in run():
        print(r)
