"""Roofline table assembly: reads results/dryrun/*.json into the
EXPERIMENTS.md §Roofline table and the per-cell bottleneck report.

Emits CSV rows: name,us_per_call,derived  (us_per_call = modelled step-time
bound in microseconds, from the dominant roofline term).
"""
from __future__ import annotations

import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load(mesh: str = "1pod") -> list[dict]:
    recs = []
    for f in sorted(RESULTS.glob(f"*_{mesh}.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def rows(mesh: str = "1pod") -> list[str]:
    out = []
    for r in load(mesh):
        cell = r["cell"]
        if r.get("status") != "ok":
            out.append(f"roofline_{cell},0,{r.get('status')}")
            continue
        rf = r["roofline"]
        bound_us = rf["step_time_bound_s"] * 1e6
        out.append(
            f"roofline_{cell},{bound_us:.0f},"
            f"dom={rf['dominant'][:-2]} comp={rf['compute_s']:.3f}s "
            f"mem={rf['memory_s']:.3f}s coll={rf['collective_s']:.3f}s "
            f"useful={rf['useful_flops_ratio']:.2f} "
            f"peak={r['memory']['peak_bytes_per_device']/2**30:.2f}GiB")
    return out


def markdown_table(mesh: str = "1pod") -> str:
    lines = [
        "| cell | status | compute s | memory s | collective s | dominant "
        "| MODEL_FLOPS | useful ratio | peak GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load(mesh):
        cell = r["cell"].replace(f"_{mesh}", "")
        if r.get("status") != "ok":
            lines.append(f"| {cell} | {r.get('status')} | | | | | | | |")
            continue
        rf = r["roofline"]
        lines.append(
            f"| {cell} | ok | {rf['compute_s']:.3f} | {rf['memory_s']:.3f} "
            f"| {rf['collective_s']:.3f} | **{rf['dominant'][:-2]}** "
            f"| {rf['model_flops']:.2e} | {rf['useful_flops_ratio']:.2f} "
            f"| {r['memory']['peak_bytes_per_device']/2**30:.2f} |")
    return "\n".join(lines)


def run() -> list[str]:
    return rows("1pod")


if __name__ == "__main__":
    import sys
    if "--markdown" in sys.argv:
        print(markdown_table("1pod"))
        print()
        print(markdown_table("2pod"))
    else:
        for r in run():
            print(r)
