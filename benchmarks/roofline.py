"""Roofline table assembly: reads results/dryrun/*.json into the
EXPERIMENTS.md §Roofline table and the per-cell bottleneck report.

Emits CSV rows: name,us_per_call,derived  (us_per_call = modelled step-time
bound in microseconds, from the dominant roofline term).
"""
from __future__ import annotations

import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load(mesh: str = "1pod") -> list[dict]:
    recs = []
    for f in sorted(RESULTS.glob(f"*_{mesh}.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def fused_bridge_rows() -> list[str]:
    """Analytic bytes/flop of the fused bridge kernels (BENCH geometry).

    The serve/gather/commit pair (kernels/bridge_gather.py) is pure data
    movement — per round it reads and writes each of the L in-flight lanes'
    pages once on each side of the wire, zero FLOPs, so its roofline point
    sits on the memory axis: the epoch is won or lost on dispatch and copy
    elimination, which is exactly what the fused pallas_call removes (see
    BENCH_bridge.json's ``fused`` section for the measured confirmation).
    The streaming decode-attention kernel (kernels/bridge_attention.py)
    does 4*T*hd FLOPs per head-lane visit over a (T, kv, hd) f32 page pair
    read once — its bytes/flop shows it compute-dense enough that folding
    it into the pull loop costs no memory-bound slack.
    """
    page_bytes = 1 << 18
    lanes = 8
    gather_bytes = 2 * 2 * lanes * page_bytes  # rd+wr, gather + commit
    out = [
        f"roofline_fused_bridge_gather,0,bytes/round={gather_bytes} "
        f"flops=0 pure_movement (L={lanes} x {page_bytes >> 10}KiB pages, "
        f"rd+wr both kernels)"]
    t, kv, hd, h = 4, 2, 16, 8
    flops = 4 * t * hd * h          # qk^T + pv per head over one page pair
    bytes_ = 2 * t * kv * hd * 4    # k + v page read once (f32)
    out.append(
        f"roofline_fused_stream_attn,0,bytes/lane={bytes_} "
        f"flops/lane={flops} bytes_per_flop={bytes_ / flops:.2f} "
        f"(T={t} kv={kv} hd={hd} H={h})")
    return out


def rows(mesh: str = "1pod") -> list[str]:
    out = []
    for r in load(mesh):
        cell = r["cell"]
        if r.get("status") != "ok":
            out.append(f"roofline_{cell},0,{r.get('status')}")
            continue
        rf = r["roofline"]
        bound_us = rf["step_time_bound_s"] * 1e6
        out.append(
            f"roofline_{cell},{bound_us:.0f},"
            f"dom={rf['dominant'][:-2]} comp={rf['compute_s']:.3f}s "
            f"mem={rf['memory_s']:.3f}s coll={rf['collective_s']:.3f}s "
            f"useful={rf['useful_flops_ratio']:.2f} "
            f"peak={r['memory']['peak_bytes_per_device']/2**30:.2f}GiB")
    return out


def markdown_table(mesh: str = "1pod") -> str:
    lines = [
        "| cell | status | compute s | memory s | collective s | dominant "
        "| MODEL_FLOPS | useful ratio | peak GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load(mesh):
        cell = r["cell"].replace(f"_{mesh}", "")
        if r.get("status") != "ok":
            lines.append(f"| {cell} | {r.get('status')} | | | | | | | |")
            continue
        rf = r["roofline"]
        lines.append(
            f"| {cell} | ok | {rf['compute_s']:.3f} | {rf['memory_s']:.3f} "
            f"| {rf['collective_s']:.3f} | **{rf['dominant'][:-2]}** "
            f"| {rf['model_flops']:.2e} | {rf['useful_flops_ratio']:.2f} "
            f"| {r['memory']['peak_bytes_per_device']/2**30:.2f} |")
    return "\n".join(lines)


def run() -> list[str]:
    return rows("1pod") + fused_bridge_rows()


if __name__ == "__main__":
    import sys
    if "--markdown" in sys.argv:
        print(markdown_table("1pod"))
        print()
        print(markdown_table("2pod"))
    else:
        for r in run():
            print(r)
