"""Serving example: batched requests decoding against the SAME model under
three KV placements — local dense, bridge-pull (paper-faithful) and
bridge-push (beyond-paper compute-at-memory) — asserting the outputs agree
and reporting step timings.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.config import RunConfig, ShapeConfig
from repro.models import transformer
from repro.serve import step as serve_step_mod

BATCH, MAX_LEN, STEPS, PAGE_TOKENS = 4, 64, 24, 8


def decode(run, params, kv, prompt):
    cache_ops = serve_step_mod.make_cache_ops(
        run, mesh=None, max_len=MAX_LEN, page_tokens=PAGE_TOKENS,
        dtype=jnp.float32)
    state = serve_step_mod.init_serve_state(run, BATCH, cache_ops)
    step = jax.jit(serve_step_mod.build_serve_step(run, cache_ops),
                   donate_argnums=(1,))
    tokens = prompt
    out = []
    t0 = time.monotonic()
    for _ in range(STEPS):
        tokens, state = step(params, state, tokens)
        out.append(np.asarray(tokens))
    jax.block_until_ready(tokens)
    return np.stack(out, 1), (time.monotonic() - t0) / STEPS


def main():
    cfg = dataclasses.replace(configs.get_reduced("granite-3-8b"),
                              dtype="float32")
    shape = ShapeConfig("example", MAX_LEN, BATCH, "decode")
    params = transformer.init_params(cfg, jax.random.key(0))
    prompt = jnp.asarray([1, 2, 3, 4], jnp.int32)

    results = {}
    for kv in ("local", "bridge_pull", "bridge_push"):
        run = RunConfig(model=cfg, shape=shape, kv_placement=kv)
        toks, ms = decode(run, params, kv, prompt)
        results[kv] = toks
        print(f"{kv:12s}  {ms*1e3:7.1f} ms/step   "
              f"sample: {toks[0][:10].tolist()}")

    np.testing.assert_array_equal(results["local"], results["bridge_pull"])
    np.testing.assert_array_equal(results["local"], results["bridge_push"])
    print("OK: all three KV placements decode identical tokens")


if __name__ == "__main__":
    main()
