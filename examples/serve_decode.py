"""Serving example: a two-tenant disaggregated pool under orchestration.

Batched requests decode against the SAME model under three KV placements —
local dense, bridge-pull (paper-faithful) and bridge-push (beyond-paper
compute-at-memory) — asserting the outputs agree and reporting step
timings.  The bridge placements then run again **multi-tenant**: the batch
splits between an interactive "chat" tenant and a batch "crawl" tenant
driven through ``repro.orchestrator`` — tenants register, lease pooled
pages under admission control, the decode steps attribute every bridge
transfer to its tenant via the telemetry lane, and the measured per-tenant
demand re-fits the orchestrator's weighted-fair QoS windows.  Attribution
is observational, so the two-tenant decode emits bit-identical tokens.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.config import RunConfig, ShapeConfig
from repro.models import transformer
from repro.serve import step as serve_step_mod

BATCH, MAX_LEN, STEPS, PAGE_TOKENS = 4, 64, 24, 8


def decode(run, params, kv, prompt, tenant_of_seq=None, max_tenants=0,
           collect_telemetry=False):
    cache_ops = serve_step_mod.make_cache_ops(
        run, mesh=None, max_len=MAX_LEN, page_tokens=PAGE_TOKENS,
        collect_telemetry=collect_telemetry, tenant_of_seq=tenant_of_seq,
        max_tenants=max_tenants, dtype=jnp.float32)
    state = serve_step_mod.init_serve_state(run, BATCH, cache_ops)
    step = jax.jit(serve_step_mod.build_serve_step(run, cache_ops),
                   donate_argnums=(1,))
    tokens = prompt
    out = []
    t0 = time.monotonic()
    for _ in range(STEPS):
        tokens, state = step(params, state, tokens)
        out.append(np.asarray(tokens))
    jax.block_until_ready(tokens)
    return np.stack(out, 1), (time.monotonic() - t0) / STEPS, state


def two_tenant_demo(run, params, prompt, baseline):
    """Drive the same bridge_pull decode as two orchestrated tenants."""
    from repro.core.control_plane import ControlPlane
    from repro.orchestrator import Orchestrator, TenantSpec

    # sequence b belongs to tenant b % 2: chat gets 0 and 2, crawl 1 and 3
    tenant_of_seq = np.arange(BATCH) % 2
    cp = ControlPlane(1, BATCH * (MAX_LEN // PAGE_TOKENS),
                      num_logical=BATCH * (MAX_LEN // PAGE_TOKENS))
    orc = Orchestrator(cp, budget=run.bridge.epoch_budget, control_period=1,
                       max_tenants=2, migrate=False)
    orc.register(TenantSpec(0, "chat", qos="interactive", share=3.0))
    orc.register(TenantSpec(1, "crawl", qos="batch", share=1.0))
    for tid in (0, 1):
        dec, lease = orc.request_lease(
            tid, int((tenant_of_seq == tid).sum()) * (MAX_LEN // PAGE_TOKENS))
        assert dec.admitted and lease is not None

    toks, ms, state = decode(run, params, "bridge_pull", prompt,
                             tenant_of_seq=tenant_of_seq, max_tenants=2,
                             collect_telemetry=True)
    np.testing.assert_array_equal(baseline, toks)
    telem = serve_step_mod.collect_state_telemetry(state)
    rep = orc.step(telem)
    served = np.asarray(telem.tenant_served).sum(0)
    print(f"two-tenant    {ms*1e3:7.1f} ms/step   chat served "
          f"{int(served[0])} pages, crawl {int(served[1])} "
          f"(windows after re-fit: {rep['windows']})")
    print(orc.describe())
    print("OK: two-tenant bridge decode is bit-identical (attribution is "
          "observational)")


def main():
    cfg = dataclasses.replace(configs.get_reduced("granite-3-8b"),
                              dtype="float32")
    shape = ShapeConfig("example", MAX_LEN, BATCH, "decode")
    params = transformer.init_params(cfg, jax.random.key(0))
    prompt = jnp.asarray([1, 2, 3, 4], jnp.int32)

    results = {}
    for kv in ("local", "bridge_pull", "bridge_push"):
        run = RunConfig(model=cfg, shape=shape, kv_placement=kv)
        toks, ms, _ = decode(run, params, kv, prompt)
        results[kv] = toks
        print(f"{kv:12s}  {ms*1e3:7.1f} ms/step   "
              f"sample: {toks[0][:10].tolist()}")

    np.testing.assert_array_equal(results["local"], results["bridge_pull"])
    np.testing.assert_array_equal(results["local"], results["bridge_push"])
    print("OK: all three KV placements decode identical tokens")

    run = RunConfig(model=cfg, shape=shape, kv_placement="bridge_pull")
    two_tenant_demo(run, params, prompt, results["bridge_pull"])


if __name__ == "__main__":
    main()
