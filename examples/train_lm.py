"""End-to-end training driver: ~100M-param LM, few hundred steps, with
checkpointing, resume, a mid-run simulated node failure, and disaggregated
optimizer state through the bridge.

This is the (b) deliverable's end-to-end example.  By default it runs a
~15M reduced model for 60 steps so CPU CI finishes in minutes; pass
``--full-100m --steps 300`` for the real thing (same code path).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 60]
"""
import argparse
import dataclasses
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.config import OptimConfig, RunConfig, ShapeConfig
from repro.core import zero_bridge
from repro.core.control_plane import ControlPlane
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.ft.elastic import ElasticTrainer
from repro.train import step as train_step_mod


def build(args):
    cfg = configs.get_reduced("granite-3-8b")
    if args.full_100m:
        cfg = dataclasses.replace(
            cfg, num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
            head_dim=64, d_ff=2048, vocab_size=32768)
    shape = ShapeConfig("example", args.seq, args.batch, "train")
    run = RunConfig(model=cfg, shape=shape,
                    optim=OptimConfig(lr=3e-4, warmup_steps=20,
                                      total_steps=args.steps))
    return run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--fail-at", type=int, default=35,
                    help="simulate a node failure at this step (0=off)")
    args = ap.parse_args()

    run = build(args)
    state = train_step_mod.make_train_state(run, jax.random.key(0))
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(state.params))
    print(f"model={run.model.name}(reduced) params={n/1e6:.1f}M")

    # Disaggregated optimizer state: the AdamW moments live in a bridge pool
    # (4 logical memory nodes; loopback circuit on 1 CPU device).
    cp = ControlPlane(num_nodes=4, pages_per_node=4096, num_logical=8192)
    store = zero_bridge.create_store(state.opt.m, mesh=None,
                                     page_elems=4096, cp=cp)
    print("optimizer-moment pool:", cp.occupancy().tolist(), "pages/node")

    step_fn = jax.jit(train_step_mod.build_train_step(run),
                      donate_argnums=(0,))
    with tempfile.TemporaryDirectory() as ckdir:
        ckpt = CheckpointManager(ckdir, keep=2)
        trainer = ElasticTrainer(step_fn=step_fn, ckpt=ckpt, cp=cp,
                                 ckpt_every=20)
        data = SyntheticLM(run.model, args.batch, args.seq)
        batches = ({k: jnp.asarray(v) for k, v in b.items()}
                   for b in Prefetcher(data.iterate(), depth=2))
        failure = {args.fail_at: 2} if args.fail_at else None

        t0 = time.monotonic()
        state, history = trainer.run(state, batches, num_steps=args.steps,
                                     failure_schedule=failure)
        dt = time.monotonic() - t0

    losses = [h["loss"] for h in history]
    head = float(np.mean(losses[:5]))
    tail = float(np.mean(losses[-5:]))
    print(f"steps={len(history)} wall={dt:.1f}s "
          f"loss {head:.3f} -> {tail:.3f}")
    for ev in trainer.events:
        print(f"  event: {ev.kind} node={ev.node} step={ev.at_step}")
    assert tail < head, "loss should decrease"
    # pool placement after the failure excludes the dead node
    assert not np.any(np.asarray(cp.table().home) == 2)
    print("OK: trained through a node failure with elastic remap")


if __name__ == "__main__":
    main()
