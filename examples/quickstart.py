"""Quickstart: the software-defined bridge in 80 lines.

Demonstrates the paper's core loop end-to-end on CPU:
  1. a control plane allocates a pooled memory region,
  2. a memport table is programmed (software-defined placement),
  3. a master pulls pages through the circuit-epoch transfer engine,
  4. the region is re-homed at runtime (elastic remap) WITHOUT recompiling
     the pull step — the table is just data.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bridge, ref
from repro.core.control_plane import ControlPlane
from repro.core.memport import FREE

NODES, SLOTS, PAGE = 4, 16, 64  # a tiny 4-node pod (1 CPU device: loopback)


def main():
    # 1. control plane owns placement
    cp = ControlPlane(num_nodes=NODES, pages_per_node=SLOTS, num_logical=32)
    region = cp.allocate(12, "tensor-A", policy="striped")
    print(cp.describe())

    # 2. pool contents (each row = one page of a disaggregated tensor)
    rng = np.random.default_rng(0)
    pool = jnp.asarray(rng.normal(size=(NODES * SLOTS, PAGE)).astype(
        np.float32))

    # 3. a master requests pages 0..11 — the bridge translates through the
    #    memport table and pulls them over ring-circuit epochs
    table = cp.table()
    want = jnp.asarray([[0, 5, 3, FREE, 11, 7]], jnp.int32)
    pull = jax.jit(lambda pool, want, table: bridge.pull_pages(
        pool, want, table, mesh=None, budget=4, table_nodes=NODES))
    got = pull(pool, want, table)
    exp = ref.pull_pages_ref(pool, want, table, pages_per_node=SLOTS)
    np.testing.assert_allclose(got, exp)
    print("pull through bridge == direct gather  OK")

    # 4. elastic remap: node 2 dies; pages re-home; SAME jitted fn, new table
    plan = cp.fail_node(2)
    print(f"node 2 failed: {len(plan)} pages re-homed")
    table2 = cp.table()
    # executor restores migrated page contents (here: from the old image)
    pool_np = np.array(pool)
    for step in plan:
        old = step.old_home * SLOTS + step.old_slot
        new = step.new_home * SLOTS + step.new_slot
        pool_np[new] = pool_np[old]
    got2 = pull(jnp.asarray(pool_np), want, table2)   # no recompile
    np.testing.assert_allclose(got2, exp)
    print("post-remap pull identical, zero recompilation  OK")
    print(cp.describe())


if __name__ == "__main__":
    main()
